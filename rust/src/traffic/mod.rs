//! Online serving layer: open-loop/closed-loop traffic over the batch
//! scheduler, with tail-latency accounting (the ISSUE-4 tentpole).
//!
//! Every other experiment in this repo is *batch drain*: the corpus
//! starts fully queued and the figure of merit is makespan. A storage
//! fleet serving millions of users is measured differently — requests
//! arrive over time, and the figure of merit is **tail latency at an
//! offered load**, plus the highest load the system sustains under a
//! p99 SLO. This module adds that dimension without duplicating any
//! service-time modeling:
//!
//! * [`arrivals`] — deterministic request generators: open-loop Poisson,
//!   open-loop bursty (on/off MMPP-style), and a closed loop (N clients
//!   × think time). Open loops keep offering load when the system
//!   congests (the honest saturation probe — no coordinated omission);
//!   closed loops self-throttle and probe capacity instead. See the
//!   submodule docs for the tradeoff.
//! * [`engine`] — the serving frontend: requests queue per drive, a
//!   **size-or-timeout** formation gate releases them, and dispatch runs
//!   through the *batch* scheduler's own
//!   [`crate::sched::SchedState`] dispatch bodies in either
//!   [`DispatchMode`] — polling quantizes dispatch to the paper's wake
//!   grid (its latency tax is visible in every percentile), event-driven
//!   dispatches on arrival/ack.
//! * [`balancer`] — fleet serving: a front-door load balancer
//!   (round-robin / weighted-by-capacity / join-shortest-queue) spreads
//!   the stream over [`crate::cluster::fleet`] servers; responses from
//!   non-head servers pay the top-of-rack link
//!   ([`crate::interconnect::RackLink`], FIFO at the head's downlink).
//!
//! Per-request latency = queue wait + batch formation + service; the
//! report carries exact p50/p95/p99/p99.9 over the full sample set
//! ([`crate::util::stats::Summary`] — no sketches). Experiment Fig 9
//! ([`crate::exp::fig9_latency`], `solana fig9`, `solana serve`,
//! `cargo bench --bench serve_latency`) sweeps offered load × fleet
//! shape × app and reports the **max sustainable throughput**: the
//! highest offered load whose p99 stays under the SLO.
//!
//! On top of the PR-4 data plane sits the serving **control plane**
//! (the ISSUE-5 tentpole):
//!
//! * **SLO-aware admission control** — `[traffic] admission = true` /
//!   `solana serve --admission on` sheds requests whose estimated wait
//!   would blow the p99-SLO deadline budget, with exact accounting
//!   (`offered == accepted + shed`; shed requests are excluded from the
//!   percentiles and reported as goodput loss). See [`engine`].
//! * **latency-aware balancing** — the `least-work` front-door policy
//!   routes on outstanding *service time* (queued requests × per-shape
//!   service estimate) instead of request count, which is what saves a
//!   heterogeneous fleet when count-based JSQ pins on a slow, shedding
//!   server. See [`balancer`].
//! * **hot-shard skew** — `[traffic] skew` / `--skew` warps per-drive
//!   data placement toward a Zipf-like distribution to stress both of
//!   the above. See [`engine`].
//! * **autoscaling** — Fig 10 ([`crate::exp::fig10_autoscale`],
//!   `solana fig10`, `cargo bench --bench serve_autoscale`) reports the
//!   minimum servers each fleet shape needs to meet the p99 SLO as the
//!   offered load grows, plus goodput and per-request energy at that
//!   operating point.
//!
//! And on top of the control plane sits the **failure plane** (the
//! ISSUE-6 tentpole): deterministic fault injection ([`crate::faults`])
//! answered by a front-door resilience layer — per-request
//! deadline-aware timeouts with a capped exponential-backoff retry
//! budget (`[traffic] retries`), hedged requests with
//! first-response-wins duplicate suppression (`[traffic] hedge`), and
//! missed-ack dead-server detection with shard failover to a neighbor
//! replica over the rack link (`[fleet] replicas`). Fig 11
//! ([`crate::exp::fig11_availability`], `solana fig11`,
//! `cargo bench --bench serve_faults`) measures availability (fraction
//! of offered requests completed within the SLO) across fault scenario
//! × resilience policy × fleet shape.
//!
//! Finally the **elastic plane** (the ISSUE-10 tentpole) makes fleet
//! membership time-varying inside one run: an autoscaler
//! ([`elastic::AutoscaleConfig`], `[autoscale]` / `solana serve
//! --autoscale`) joins and drains servers against the observed p99 vs
//! the SLO, and a shard rebalancer migrates hot shards between servers
//! with the migration priced as shard bytes over the rack link. Fig 12
//! ([`crate::exp::fig12_elastic`], `solana fig12`,
//! `cargo bench --bench serve_elastic`) ramps offered load (plus a
//! flash crowd) and compares elastic server-seconds against the best
//! static fleet from fig10.

pub mod arrivals;
pub mod balancer;
pub mod elastic;
pub(crate) mod engine;

pub use arrivals::{ArrivalProcess, Arrivals, Request};
pub use balancer::{serve_fleet, serve_fleet_traced, LbPolicy};
pub use elastic::{parse_autoscale_policy, AutoscaleConfig, AutoscalePolicy};
pub use engine::FormationPolicy;

use crate::cluster::fleet::{FleetConfig, FleetShape, ServerSpec};
use crate::faults::FaultsConfig;
use crate::metrics::Metrics;
use crate::power::PowerModel;
use crate::sched::SchedConfig;
use crate::util::stats::Summary;
use crate::workloads::{App, AppModel};

/// Traffic configuration for one serving run — the `[traffic]` TOML
/// section and the `solana serve` flags both resolve into this.
#[derive(Clone, Debug)]
pub struct TrafficConfig {
    /// Which arrival process generates the request timeline.
    pub process: ArrivalProcess,
    /// Offered load as a fraction of the fleet's nominal capacity
    /// (open-loop processes; ignored when `rate_rps` is set).
    pub load: f64,
    /// Absolute offered rate override, requests/s.
    pub rate_rps: Option<f64>,
    /// Total requests in the run.
    pub requests: u64,
    /// Batch-formation size gate: dispatch waits for this many queued
    /// requests (or the timeout). 1 = dispatch immediately.
    pub min_batch: u64,
    /// Batch-formation timeout: the oldest queued request never waits
    /// longer than this for companions.
    pub batch_timeout_s: f64,
    /// Closed-loop client count.
    pub clients: usize,
    /// Closed-loop mean think time (s).
    pub think_s: f64,
    /// Bursty peak/mean ratio.
    pub burstiness: f64,
    /// Bursty mean ON-window length (s).
    pub burst_on_s: f64,
    /// Front-door load-balancer policy (fleet serving).
    pub policy: LbPolicy,
    /// p99 SLO override (s); `None` derives a per-app default from the
    /// CSD batch service time (see [`default_slo_p99`]).
    pub slo_p99_s: Option<f64>,
    /// SLO-aware admission control (the ISSUE-5 tentpole): shed
    /// requests whose estimated wait would blow the p99-SLO deadline
    /// budget instead of queuing them. Off by default — the PR-4
    /// serve-everything behavior.
    pub admission: bool,
    /// Hot-shard placement skew: Zipf-like per-drive weighting exponent
    /// (`w_d ∝ 1/(d+1)^skew`). 0 = uniform round-robin (default).
    pub skew: f64,
    /// Deterministic seed for the arrival generators.
    pub seed: u64,
    /// Retry budget per request (ISSUE-6): after a deadline-aware
    /// timeout the front door re-submits, with capped exponential
    /// backoff, up to this many times before declaring the request
    /// failed. 0 (default) disables the timeout/retry layer entirely.
    pub retries: u32,
    /// Base retry timeout (s). `None` (default) derives a deadline-aware
    /// base from the target engine's completion estimate — generous
    /// enough that it never fires on a healthy fleet. Set explicitly for
    /// tight recovery (fig11 uses `0.5 × SLO`).
    pub retry_timeout_s: Option<f64>,
    /// Hedged requests (ISSUE-6): after a fraction of the first-timeout
    /// base the front door speculatively duplicates a straggler to a
    /// second server; first response wins, the loser is suppressed.
    pub hedge: bool,
    /// Fault-injection plan (ISSUE-6). `None` (default) is the exact
    /// fault-free path; `Some` with all-zero rates is bit-identical to
    /// it (property-tested in `tests/chaos.rs`).
    pub faults: Option<FaultsConfig>,
    /// Background ingest/update rate per server, item-sized writes/s
    /// (ISSUE-8): a seeded Poisson stream of in-place corpus updates
    /// that runs the full device write path during the arrival window,
    /// so FTL garbage collection interferes with query latency. 0
    /// (default) arms nothing — the exact read-only serving path.
    pub ingest_rate: f64,
    /// Elastic-fleet autoscaler + shard rebalancer (ISSUE-10). `None`
    /// (default) is the exact static-membership path — the elastic
    /// layer contributes nothing to the event race and mutates no
    /// state (property-tested in `tests/chaos.rs`).
    pub autoscale: Option<AutoscaleConfig>,
    /// Time-varying offered load for the Poisson process (ISSUE-10):
    /// `(duration_s, rate_multiplier)` segments applied in order to the
    /// resolved offered rate; the last segment extends forever. `None`
    /// (default) keeps the exact fixed-rate Poisson draw sequence.
    /// Programmatic only (fig12 builds the ramp + flash-crowd shapes);
    /// not exposed as a TOML/CLI knob.
    pub rate_segments: Option<Vec<(f64, f64)>>,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            process: ArrivalProcess::Poisson,
            load: 0.5,
            rate_rps: None,
            requests: 10_000,
            min_batch: 1,
            batch_timeout_s: 0.05,
            clients: 64,
            think_s: 1.0,
            burstiness: 4.0,
            burst_on_s: 1.0,
            policy: LbPolicy::JoinShortestQueue,
            slo_p99_s: None,
            admission: false,
            skew: 0.0,
            seed: 42,
            retries: 0,
            retry_timeout_s: None,
            hedge: false,
            faults: None,
            ingest_rate: 0.0,
            autoscale: None,
            rate_segments: None,
        }
    }
}

impl TrafficConfig {
    pub fn formation(&self) -> FormationPolicy {
        FormationPolicy { min_batch: self.min_batch, timeout_s: self.batch_timeout_s }
    }

    /// Whether the timeout/retry/hedge resilience layer is armed.
    pub fn resilient(&self) -> bool {
        self.retries > 0 || self.hedge
    }

    /// Resolve the offered rate against a fleet's nominal capacity.
    /// Closed loops have no offered rate; their upper bound is
    /// `clients / think_s` (every client permanently in think+serve
    /// rotation).
    pub fn offered_rps(&self, fleet_nominal: f64) -> f64 {
        match self.process {
            ArrivalProcess::ClosedLoop => self.clients as f64 / self.think_s,
            _ => self.rate_rps.unwrap_or(self.load * fleet_nominal),
        }
    }

    /// Build the arrival stream for this config at `offered` req/s.
    pub fn arrivals(&self, offered: f64) -> Arrivals {
        match self.process {
            ArrivalProcess::Poisson => match &self.rate_segments {
                Some(segs) => {
                    let abs: Vec<(f64, f64)> =
                        segs.iter().map(|&(d, m)| (d, m * offered)).collect();
                    Arrivals::ramped(&abs, self.requests, self.seed)
                }
                None => Arrivals::poisson(offered, self.requests, self.seed),
            },
            ArrivalProcess::Bursty => {
                Arrivals::bursty(offered, self.burstiness, self.burst_on_s, self.requests, self.seed)
            }
            ArrivalProcess::ClosedLoop => {
                Arrivals::closed_loop(self.clients, self.think_s, self.requests, self.seed)
            }
        }
    }
}

/// Deterministic smooth weighted rotation: pick the index whose
/// realized share lags its weight share most — argmin of
/// `(count + 1) / weight`, ties to the lowest index. Uniform weights
/// reproduce plain round-robin `0,1,…,n-1,0,…` exactly. Shared by the
/// engine's skewed data placement and the balancer's weighted /
/// least-work policies (same scoring, different counts and weights).
pub(crate) fn smooth_pick(counts: &[u64], weights: &[f64]) -> usize {
    debug_assert_eq!(counts.len(), weights.len());
    let mut best = 0usize;
    let mut best_score = f64::INFINITY;
    for (i, (&n, &w)) in counts.iter().zip(weights).enumerate() {
        let score = (n + 1) as f64 / w.max(1e-12);
        if score < best_score {
            best_score = score;
            best = i;
        }
    }
    best
}

/// Steady-state service capacity of one server (items/s), ignoring
/// batch overheads: host threads plus every engaged ISP core. Offered
/// loads are expressed as fractions of this (overheads push the real
/// knee below 1.0).
pub fn nominal_rate(model: &AppModel, cfg: &SchedConfig) -> f64 {
    let host = if cfg.use_host { model.host_rate() } else { 0.0 };
    host + cfg.isp_drives as f64 * model.csd_rate()
}

/// Fleet-wide nominal capacity: the sum over resolved server specs.
pub fn fleet_nominal_rate(model: &AppModel, specs: &[ServerSpec]) -> f64 {
    specs.iter().map(|s| nominal_rate(model, &s.sched)).sum()
}

/// Default p99 SLO: 4× the CSD batch service time at the configured
/// batch size — generous enough that in-storage service (the slow but
/// plentiful path) meets it with headroom, tight enough that queueing
/// blowup past the knee violates it. Shape-independent by construction
/// (it depends only on the app model and the shared batch template), so
/// all-CSD and all-SSD fleets are judged against the same bar.
pub fn default_slo_p99(model: &AppModel, csd_batch: u64) -> f64 {
    4.0 * (model.csd_batch_overhead
        + csd_batch as f64 * model.csd_item_secs / crate::workloads::ISP_CORES)
}

/// Exact latency percentiles over the full per-request sample set.
#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    pub max: f64,
}

impl LatencyStats {
    pub(crate) fn of(samples: &[f64]) -> LatencyStats {
        match Summary::of(samples) {
            Some(s) => LatencyStats {
                mean: s.mean,
                p50: s.p50,
                p95: s.p95,
                p99: s.p99,
                p999: s.p999,
                max: s.max,
            },
            None => LatencyStats { mean: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, p999: 0.0, max: 0.0 },
        }
    }
}

/// Per-server slice of a serving run.
#[derive(Clone, Debug)]
pub struct ServerServeStats {
    pub index: usize,
    pub is_csd: bool,
    /// Requests this server completed.
    pub served: u64,
    /// Requests this server's admission gate shed.
    pub shed: u64,
    pub host_items: u64,
    pub csd_items: u64,
    pub host_busy_secs: f64,
    pub isp_busy_secs: f64,
}

/// One autoscaler observation window of an elastic run (ISSUE-10) —
/// the fig12 time-series row source. Static runs have an empty
/// timeline.
#[derive(Clone, Debug)]
pub struct FleetSample {
    /// Window end, seconds since the first arrival.
    pub t: f64,
    /// Servers actively taking new work at the window end.
    pub active: usize,
    /// Servers draining (finishing in-flight work, taking nothing new).
    pub draining: usize,
    /// p99 over the requests completed inside this window (0 if none).
    pub p99_s: f64,
    /// Requests that arrived inside this window.
    pub arrived: u64,
    /// Requests completed inside this window.
    pub served: u64,
    /// Requests shed inside this window.
    pub shed: u64,
    /// Estimated fleet energy spent inside this window (active servers
    /// × window host-busy energy).
    pub energy_j: f64,
}

/// Everything a serving run produces — the Fig 9 row source.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub app: &'static str,
    pub shape: &'static str,
    pub dispatch: &'static str,
    pub process: &'static str,
    pub policy: &'static str,
    pub servers: usize,
    pub requests: u64,
    /// Requests accepted and completed (`requests − shed` on a healthy
    /// fleet; under faults `requests == served + failed + shed`).
    pub served: u64,
    /// Requests shed by admission control (0 with admission off).
    /// Exact accounting: `requests == served + failed + shed`, always.
    pub shed: u64,
    /// Requests that exhausted their retry budget (or had none) after a
    /// fault destroyed every attempt. 0 on a fault-free run.
    pub failed: u64,
    /// Retry re-submissions issued by the front door. Excluded from the
    /// exactly-once accounting above: a retry is another attempt at the
    /// same request, never a new request.
    pub retried: u64,
    /// Hedged duplicates issued by the front door (first response wins).
    pub hedged: u64,
    /// Extra responses discarded by first-response-wins bookkeeping
    /// (late hedge losers, rack-link duplicates, post-retry stragglers).
    pub duplicate_suppressed: u64,
    /// Requests completed within the p99 SLO — the availability
    /// numerator.
    pub completed_in_slo: u64,
    /// Fraction of *offered* requests completed within the SLO — the
    /// fig11 availability metric. Shed, failed, and SLO-late requests
    /// all count against it.
    pub availability: f64,
    /// Whether SLO-aware admission control was active.
    pub admission: bool,
    /// The p99 SLO the run was judged (and, with admission on,
    /// controlled) against — the `[traffic] slo_p99_s` override or the
    /// per-app default ([`default_slo_p99`]).
    pub slo_p99_s: f64,
    /// Configured offered rate (closed loop: the `clients/think`
    /// upper bound).
    pub offered_rps: f64,
    /// Completions per second of serving wall-clock. With admission on
    /// this is the *goodput*: shed requests never count.
    pub achieved_rps: f64,
    /// First arrival → last response (serving clock).
    pub duration_secs: f64,
    pub latency: LatencyStats,
    pub host_items: u64,
    pub csd_items: u64,
    pub host_batches: u64,
    pub csd_batches: u64,
    /// Response traffic over the top-of-rack link (fleet serving).
    pub rack_bytes: u64,
    pub rack_messages: u64,
    pub energy_j: f64,
    pub energy_per_req_j: f64,
    /// Background ingest/update writes applied fleet-wide (ISSUE-8).
    pub ingest_writes: u64,
    /// Fleet-wide flash write amplification: flash pages programmed per
    /// host page written (1.0 with no GC relocation; ≡ 1.0 under ZNS).
    pub waf: f64,
    /// GC victim collections across every drive in the fleet
    /// (foreground + background).
    pub gc_runs: u64,
    /// Worst per-drive spread between the most- and least-erased block
    /// (wear-leveling proxy).
    pub wear_spread: u32,
    /// Engine self-profiling (ISSUE-9): total simulation events the
    /// serving engines executed, fleet-wide. Like the batch report's
    /// `events_executed`, the profiling counters below are descriptive
    /// run telemetry, not simulation outputs — they are excluded from
    /// [`ServeReport::check_bit_identical`].
    pub engine_events: u64,
    /// Host batch-completion events executed fleet-wide.
    pub host_done_events: u64,
    /// CSD batch-ack events executed fleet-wide.
    pub csd_ack_events: u64,
    /// Polling-grid wake events executed fleet-wide.
    pub wake_events: u64,
    /// Formation-timeout flush events executed fleet-wide.
    pub flush_events: u64,
    /// Background-ingest write events executed fleet-wide.
    pub ingest_events: u64,
    /// Deepest per-engine request queue observed at any event.
    pub max_queue_depth: u64,
    /// Mean queue depth over events (fleet-wide event-weighted mean).
    pub mean_queue_depth: f64,
    /// Most requests simultaneously in flight on any one engine.
    pub max_inflight: u64,
    pub per_server: Vec<ServerServeStats>,
    /// Integrated server-seconds actually paid for (ISSUE-10): elastic
    /// runs sum each server's active+draining residency; static runs
    /// are exactly `servers × duration_secs`. The fig12 cost metric.
    pub server_seconds: f64,
    /// Most servers simultaneously active or draining at any point.
    /// Equals `servers` on a static run.
    pub peak_servers: usize,
    /// Shard migrations executed (joins, drains, and rebalances all
    /// move shards through this counter).
    pub migrations: u64,
    /// Bytes shipped over the rack link by shard migrations.
    pub migrated_bytes: u64,
    /// Servers activated mid-run by the autoscaler.
    pub joins: u64,
    /// Servers drained out mid-run by the autoscaler.
    pub drains: u64,
    /// Per-observation-window fleet time series (ISSUE-10); empty on a
    /// static run.
    pub timeline: Vec<FleetSample>,
}

impl ServeReport {
    /// Fraction of requests served in storage.
    pub fn csd_share(&self) -> f64 {
        if self.served == 0 {
            return 0.0;
        }
        self.csd_items as f64 / self.served as f64
    }

    /// Fraction of offered requests shed by admission control — the
    /// goodput loss the control plane traded for the bounded tail.
    pub fn shed_fraction(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.shed as f64 / self.requests as f64
    }

    /// Whether the accepted-request p99 met the run's SLO. A run that
    /// served nothing never "meets" it — an all-shed run has an empty
    /// accepted set whose percentiles collapse to zero, and admission
    /// must not be able to fake compliance by shedding everything.
    pub fn meets_slo(&self) -> bool {
        self.served > 0 && self.latency.p99 <= self.slo_p99_s
    }

    /// Field-by-field bit-identity (floats on bit patterns) — the
    /// same-seed determinism property test's comparator.
    pub fn check_bit_identical(&self, other: &ServeReport) -> Result<(), String> {
        fn f64_eq(name: &str, x: f64, y: f64) -> Result<(), String> {
            if x.to_bits() == y.to_bits() {
                Ok(())
            } else {
                Err(format!("{name}: {x:?} != {y:?} (bitwise)"))
            }
        }
        fn eq<T: PartialEq + std::fmt::Debug>(name: &str, x: T, y: T) -> Result<(), String> {
            if x == y {
                Ok(())
            } else {
                Err(format!("{name}: {x:?} != {y:?}"))
            }
        }
        eq("app", self.app, other.app)?;
        eq("shape", self.shape, other.shape)?;
        eq("dispatch", self.dispatch, other.dispatch)?;
        eq("process", self.process, other.process)?;
        eq("policy", self.policy, other.policy)?;
        eq("servers", self.servers, other.servers)?;
        eq("requests", self.requests, other.requests)?;
        eq("served", self.served, other.served)?;
        eq("shed", self.shed, other.shed)?;
        eq("failed", self.failed, other.failed)?;
        eq("retried", self.retried, other.retried)?;
        eq("hedged", self.hedged, other.hedged)?;
        eq("duplicate_suppressed", self.duplicate_suppressed, other.duplicate_suppressed)?;
        eq("completed_in_slo", self.completed_in_slo, other.completed_in_slo)?;
        f64_eq("availability", self.availability, other.availability)?;
        eq("admission", self.admission, other.admission)?;
        f64_eq("slo_p99_s", self.slo_p99_s, other.slo_p99_s)?;
        f64_eq("offered_rps", self.offered_rps, other.offered_rps)?;
        f64_eq("achieved_rps", self.achieved_rps, other.achieved_rps)?;
        f64_eq("duration_secs", self.duration_secs, other.duration_secs)?;
        f64_eq("latency.mean", self.latency.mean, other.latency.mean)?;
        f64_eq("latency.p50", self.latency.p50, other.latency.p50)?;
        f64_eq("latency.p95", self.latency.p95, other.latency.p95)?;
        f64_eq("latency.p99", self.latency.p99, other.latency.p99)?;
        f64_eq("latency.p999", self.latency.p999, other.latency.p999)?;
        f64_eq("latency.max", self.latency.max, other.latency.max)?;
        eq("host_items", self.host_items, other.host_items)?;
        eq("csd_items", self.csd_items, other.csd_items)?;
        eq("host_batches", self.host_batches, other.host_batches)?;
        eq("csd_batches", self.csd_batches, other.csd_batches)?;
        eq("rack_bytes", self.rack_bytes, other.rack_bytes)?;
        eq("rack_messages", self.rack_messages, other.rack_messages)?;
        f64_eq("energy_j", self.energy_j, other.energy_j)?;
        f64_eq("energy_per_req_j", self.energy_per_req_j, other.energy_per_req_j)?;
        eq("ingest_writes", self.ingest_writes, other.ingest_writes)?;
        f64_eq("waf", self.waf, other.waf)?;
        eq("gc_runs", self.gc_runs, other.gc_runs)?;
        eq("wear_spread", self.wear_spread, other.wear_spread)?;
        // Per-server slices too: a nondeterminism that only permutes
        // which server handled which requests conserves every fleet-wide
        // sum above but diverges here.
        eq("per_server.len", self.per_server.len(), other.per_server.len())?;
        for (a, b) in self.per_server.iter().zip(&other.per_server) {
            let i = a.index;
            eq("per_server.index", a.index, b.index)?;
            eq("per_server.is_csd", a.is_csd, b.is_csd)?;
            eq(&format!("per_server[{i}].served"), a.served, b.served)?;
            eq(&format!("per_server[{i}].shed"), a.shed, b.shed)?;
            eq(&format!("per_server[{i}].host_items"), a.host_items, b.host_items)?;
            eq(&format!("per_server[{i}].csd_items"), a.csd_items, b.csd_items)?;
            f64_eq(&format!("per_server[{i}].host_busy_secs"), a.host_busy_secs, b.host_busy_secs)?;
            f64_eq(&format!("per_server[{i}].isp_busy_secs"), a.isp_busy_secs, b.isp_busy_secs)?;
        }
        // Elastic-fleet outputs (ISSUE-10) are simulation results too.
        f64_eq("server_seconds", self.server_seconds, other.server_seconds)?;
        eq("peak_servers", self.peak_servers, other.peak_servers)?;
        eq("migrations", self.migrations, other.migrations)?;
        eq("migrated_bytes", self.migrated_bytes, other.migrated_bytes)?;
        eq("joins", self.joins, other.joins)?;
        eq("drains", self.drains, other.drains)?;
        eq("timeline.len", self.timeline.len(), other.timeline.len())?;
        for (k, (a, b)) in self.timeline.iter().zip(&other.timeline).enumerate() {
            f64_eq(&format!("timeline[{k}].t"), a.t, b.t)?;
            eq(&format!("timeline[{k}].active"), a.active, b.active)?;
            eq(&format!("timeline[{k}].draining"), a.draining, b.draining)?;
            f64_eq(&format!("timeline[{k}].p99_s"), a.p99_s, b.p99_s)?;
            eq(&format!("timeline[{k}].arrived"), a.arrived, b.arrived)?;
            eq(&format!("timeline[{k}].served"), a.served, b.served)?;
            eq(&format!("timeline[{k}].shed"), a.shed, b.shed)?;
            f64_eq(&format!("timeline[{k}].energy_j"), a.energy_j, b.energy_j)?;
        }
        Ok(())
    }
}

/// Serve one app on a single server (a 1-server fleet: the balancer
/// degenerates and the rack link carries nothing).
pub fn serve(
    app: App,
    sched: &SchedConfig,
    tcfg: &TrafficConfig,
    power: &PowerModel,
    metrics: &mut Metrics,
) -> anyhow::Result<ServeReport> {
    let fcfg = FleetConfig {
        servers: 1,
        shape: if sched.use_isp() { FleetShape::AllCsd } else { FleetShape::AllSsd },
        sched: sched.clone(),
        ..FleetConfig::default()
    };
    serve_fleet(app, &fcfg, tcfg, power, metrics)
}

/// Parse an arrival-process name from config/CLI.
pub fn parse_process(name: &str) -> anyhow::Result<ArrivalProcess> {
    match name {
        "poisson" | "open" => Ok(ArrivalProcess::Poisson),
        "bursty" | "burst" | "onoff" => Ok(ArrivalProcess::Bursty),
        "closed" | "closed-loop" | "closed_loop" => Ok(ArrivalProcess::ClosedLoop),
        other => anyhow::bail!("unknown arrival process '{other}' (expected poisson|bursty|closed)"),
    }
}

/// Parse a load-balancer policy name from config/CLI.
pub fn parse_policy(name: &str) -> anyhow::Result<LbPolicy> {
    match name {
        "rr" | "round-robin" | "round_robin" => Ok(LbPolicy::RoundRobin),
        "weighted" | "wrr" | "weighted-capacity" | "weighted_capacity" => {
            Ok(LbPolicy::WeightedCapacity)
        }
        "jsq" | "join-shortest-queue" | "join_shortest_queue" => Ok(LbPolicy::JoinShortestQueue),
        "least-work" | "least_work" | "lw" => Ok(LbPolicy::LeastWork),
        other => anyhow::bail!(
            "unknown balancer policy '{other}' (expected rr|weighted|jsq|least-work)"
        ),
    }
}

/// Parse an on/off switch (the `solana serve --admission` flag value).
pub fn parse_on_off(name: &str) -> anyhow::Result<bool> {
    match name {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        other => anyhow::bail!("expected on|off, got '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::DispatchMode;
    use crate::workloads::HOST_THREADS;

    fn sched_cfg(dispatch: DispatchMode) -> SchedConfig {
        SchedConfig {
            csd_batch: 500,
            batch_ratio: 26.0,
            drives: 8,
            isp_drives: 8,
            dispatch,
            ..SchedConfig::default()
        }
    }

    fn run_serve(
        dispatch: DispatchMode,
        process: ArrivalProcess,
        load: f64,
        requests: u64,
    ) -> ServeReport {
        let sched = sched_cfg(dispatch);
        let tcfg = TrafficConfig {
            process,
            load,
            requests,
            clients: 16,
            think_s: 0.05,
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        serve(App::Sentiment, &sched, &tcfg, &PowerModel::default(), &mut m).unwrap()
    }

    #[test]
    fn conservation_every_process_and_dispatch_mode() {
        // The ISSUE-4 satellite: every generated request is served
        // exactly once, in both dispatch modes, for all three arrival
        // processes (exactly-once is checked request-by-request at the
        // engine layer; here the end-to-end counts must agree too).
        for dispatch in [DispatchMode::Polling, DispatchMode::EventDriven] {
            for process in ArrivalProcess::all() {
                let r = run_serve(dispatch, process, 0.6, 2_000);
                assert_eq!(r.served, 2_000, "{dispatch:?}/{process:?}");
                assert_eq!(r.requests, 2_000, "{dispatch:?}/{process:?}");
                assert_eq!(
                    r.host_items + r.csd_items,
                    2_000,
                    "{dispatch:?}/{process:?}: items split must cover every request"
                );
                assert!(r.duration_secs > 0.0);
                assert!(r.latency.p50 > 0.0);
                assert!(r.latency.p50 <= r.latency.p99 && r.latency.p99 <= r.latency.max);
            }
        }
    }

    #[test]
    fn same_seed_serve_runs_are_bit_identical() {
        // The ISSUE-4 satellite: a serving run is a pure function of
        // (config, seed) — two runs agree on every field bit-for-bit.
        for process in ArrivalProcess::all() {
            let a = run_serve(DispatchMode::EventDriven, process, 0.7, 1_500);
            let b = run_serve(DispatchMode::EventDriven, process, 0.7, 1_500);
            a.check_bit_identical(&b).unwrap_or_else(|e| panic!("{process:?}: {e}"));
        }
    }

    #[test]
    fn low_load_p50_close_to_pure_service_time() {
        // The ISSUE-4 satellite: at near-zero load every request is
        // served solo by the (idle, fastest) host node, so p50 must be
        // at least the pure single-item service time and within 2× of
        // it — the frontend adds formation/queueing cost only under
        // load.
        let sched = sched_cfg(DispatchMode::EventDriven);
        let model = AppModel::for_app(App::Sentiment, 1);
        let tcfg = TrafficConfig {
            rate_rps: Some(0.5), // mean gap 2 s vs ~50 ms service: idle system
            requests: 300,
            ..TrafficConfig::default()
        };
        let mut m = Metrics::new();
        let r = serve(App::Sentiment, &sched, &tcfg, &PowerModel::default(), &mut m).unwrap();
        let pure = model.host_batch_overhead + model.host_item_secs / HOST_THREADS;
        assert!(
            r.latency.p50 >= pure,
            "p50 {} below pure service time {pure}",
            r.latency.p50
        );
        assert!(
            r.latency.p50 <= 2.0 * pure,
            "p50 {} more than 2x pure service time {pure} at near-zero load",
            r.latency.p50
        );
        assert_eq!(r.csd_items, 0, "an idle host absorbs a trickle entirely");
    }

    #[test]
    fn polling_grid_taxes_low_load_latency() {
        // The serving-layer echo of ablation A4: at low load the polling
        // frontend quantizes every dispatch to the 0.2 s grid, so p50
        // carries the grid wait the event-driven frontend avoids.
        let ev = run_serve(DispatchMode::EventDriven, ArrivalProcess::Poisson, 0.05, 500);
        let poll = run_serve(DispatchMode::Polling, ArrivalProcess::Poisson, 0.05, 500);
        assert!(
            poll.latency.p50 > ev.latency.p50,
            "polling p50 {} should exceed event-driven p50 {}",
            poll.latency.p50,
            ev.latency.p50
        );
        assert!(
            poll.latency.p50 - ev.latency.p50 < SchedConfig::default().wakeup_secs + 1e-6,
            "the gap is bounded by one wake period"
        );
    }

    #[test]
    fn open_loop_latency_grows_with_load() {
        // Same seed → higher load is a time-compressed copy of the same
        // timeline, so queueing can only push percentiles up.
        let lo = run_serve(DispatchMode::EventDriven, ArrivalProcess::Poisson, 0.3, 3_000);
        let hi = run_serve(DispatchMode::EventDriven, ArrivalProcess::Poisson, 1.3, 3_000);
        assert!(
            hi.latency.p99 > lo.latency.p99,
            "overload p99 {} should exceed light-load p99 {}",
            hi.latency.p99,
            lo.latency.p99
        );
        assert!(hi.latency.p50 >= lo.latency.p50);
        // Overload: achieved throughput saturates below offered.
        assert!(hi.achieved_rps < hi.offered_rps);
    }

    #[test]
    fn closed_loop_self_throttles() {
        // A closed loop never overloads: achieved ≤ clients/think bound
        // and the queue can hold at most `clients` requests, so p99
        // stays bounded by clients × service, not by run length.
        let r = run_serve(DispatchMode::EventDriven, ArrivalProcess::ClosedLoop, 0.5, 2_000);
        assert!(r.achieved_rps <= r.offered_rps * 1.05);
        assert_eq!(r.served, 2_000);
    }

    #[test]
    fn default_slo_is_shape_independent_and_generous() {
        let model = AppModel::for_app(App::Sentiment, 1);
        let slo = default_slo_p99(&model, 500);
        // One CSD batch fits under the SLO with room to spare.
        let one_batch = model.csd_batch_overhead + 500.0 * model.csd_item_secs / 4.0;
        assert!(slo >= 2.0 * one_batch);
    }

    /// Single speech server: per-request service times of hundreds of
    /// ms make admission bounds small enough that a few thousand
    /// requests exercise real shedding.
    fn speech_sched(dispatch: DispatchMode) -> SchedConfig {
        SchedConfig {
            csd_batch: 2,
            batch_ratio: 19.0,
            drives: 8,
            isp_drives: 8,
            dispatch,
            ..SchedConfig::default()
        }
    }

    #[test]
    fn admission_conservation_across_seed_process_and_dispatch() {
        // ISSUE-5 satellite: `offered == accepted + shed`, exactly, for
        // every seed × arrival process × dispatch mode — against an
        // overloaded server so the open-loop processes actually shed.
        for dispatch in [DispatchMode::Polling, DispatchMode::EventDriven] {
            let sched = speech_sched(dispatch);
            for process in ArrivalProcess::all() {
                for seed in [7, 42, 1234] {
                    let tcfg = TrafficConfig {
                        process,
                        load: 1.5,
                        requests: 2_500,
                        admission: true,
                        clients: 16,
                        think_s: 0.05,
                        seed,
                        ..TrafficConfig::default()
                    };
                    let mut m = Metrics::new();
                    let r = serve(
                        App::SpeechToText,
                        &sched,
                        &tcfg,
                        &PowerModel::default(),
                        &mut m,
                    )
                    .unwrap();
                    let ctx = format!("{dispatch:?}/{process:?}/seed {seed}");
                    assert_eq!(r.served + r.shed, 2_500, "{ctx}: offered == accepted + shed");
                    assert_eq!(
                        r.host_items + r.csd_items,
                        r.served,
                        "{ctx}: only accepted requests reach the scheduler"
                    );
                    if process != ArrivalProcess::ClosedLoop {
                        assert!(r.shed > 0, "{ctx}: open-loop overload must shed");
                        assert!(r.served > 0, "{ctx}: admission is not a drop-everything gate");
                    } else {
                        // A closed loop self-throttles below the bound.
                        assert_eq!(r.shed, 0, "{ctx}: closed loops never blow the budget");
                    }
                }
            }
        }
    }

    #[test]
    fn shedding_never_worsens_p99_of_accepted() {
        // ISSUE-5 satellite: admission only removes work, so the
        // accepted requests' p99 never rises. Below the knee the gate
        // never fires and the runs are identical; past it the bounded
        // tail replaces the open-loop blowup.
        for dispatch in [DispatchMode::Polling, DispatchMode::EventDriven] {
            let sched = speech_sched(dispatch);
            for process in [ArrivalProcess::Poisson, ArrivalProcess::Bursty] {
                for &load in &[0.6, 1.4] {
                    let mk = |admission| TrafficConfig {
                        process,
                        load,
                        requests: 2_500,
                        admission,
                        ..TrafficConfig::default()
                    };
                    let mut m = Metrics::new();
                    let off = serve(
                        App::SpeechToText,
                        &sched,
                        &mk(false),
                        &PowerModel::default(),
                        &mut m,
                    )
                    .unwrap();
                    let on = serve(
                        App::SpeechToText,
                        &sched,
                        &mk(true),
                        &PowerModel::default(),
                        &mut m,
                    )
                    .unwrap();
                    let ctx = format!("{dispatch:?}/{process:?}/load {load}");
                    assert!(
                        on.latency.p99 <= off.latency.p99 * 1.02,
                        "{ctx}: shedding worsened p99 of accepted: {} > {}",
                        on.latency.p99,
                        off.latency.p99
                    );
                    if load < 1.0 {
                        // The gate never fires below the knee: the runs
                        // are the same run.
                        assert_eq!(on.shed, 0, "{ctx}");
                        assert_eq!(
                            on.latency.p99.to_bits(),
                            off.latency.p99.to_bits(),
                            "{ctx}: an idle gate must not perturb the run"
                        );
                    } else {
                        assert!(on.shed > 0, "{ctx}: overload must shed");
                    }
                }
            }
        }
    }

    #[test]
    fn bad_traffic_configs_rejected() {
        let sched = sched_cfg(DispatchMode::EventDriven);
        let mut m = Metrics::new();
        let mut tcfg = TrafficConfig { min_batch: 0, ..TrafficConfig::default() };
        assert!(serve(App::Sentiment, &sched, &tcfg, &PowerModel::default(), &mut m).is_err());
        tcfg = TrafficConfig { batch_timeout_s: -1.0, ..TrafficConfig::default() };
        assert!(serve(App::Sentiment, &sched, &tcfg, &PowerModel::default(), &mut m).is_err());
        tcfg = TrafficConfig { rate_rps: Some(0.0), ..TrafficConfig::default() };
        assert!(serve(App::Sentiment, &sched, &tcfg, &PowerModel::default(), &mut m).is_err());
        tcfg = TrafficConfig { skew: -0.5, ..TrafficConfig::default() };
        assert!(serve(App::Sentiment, &sched, &tcfg, &PowerModel::default(), &mut m).is_err());
        tcfg = TrafficConfig { skew: f64::INFINITY, ..TrafficConfig::default() };
        assert!(serve(App::Sentiment, &sched, &tcfg, &PowerModel::default(), &mut m).is_err());
        tcfg = TrafficConfig { slo_p99_s: Some(-2.0), ..TrafficConfig::default() };
        assert!(serve(App::Sentiment, &sched, &tcfg, &PowerModel::default(), &mut m).is_err());
        tcfg = TrafficConfig { ingest_rate: -1.0, ..TrafficConfig::default() };
        assert!(serve(App::Sentiment, &sched, &tcfg, &PowerModel::default(), &mut m).is_err());
        tcfg = TrafficConfig { ingest_rate: f64::NAN, ..TrafficConfig::default() };
        assert!(serve(App::Sentiment, &sched, &tcfg, &PowerModel::default(), &mut m).is_err());
    }
}
