//! Chaos-plane integration tests (ISSUE-6): the determinism contract of
//! the fault-injection layer, checked end-to-end through `serve_fleet`.
//!
//! Three properties:
//! 1. **Quiet plans are free** — a fault plan with every rate zero must
//!    produce a report bit-identical to `faults: None`, whatever the
//!    resilience knobs, fleet shape, or balancer policy. The chaos
//!    machinery may not perturb a single float on a healthy fleet.
//! 2. **Faulted runs are reproducible** — the same fault seed over the
//!    same config yields a bit-identical report, heavy mixed faults and
//!    all.
//! 3. **Conservation survives chaos** — under any random fault plan,
//!    every offered request is accounted for exactly once:
//!    `served + failed + shed == requests`.
//!
//! The elastic plane (ISSUE-10) extends the same contract: an autoscaler
//! left disabled must be bit-free (property 4), and conservation must
//! survive joins, drains, and mid-migration faults all at once
//! (property 5).

use solana_isp::cluster::fleet::{FleetConfig, FleetShape};
use solana_isp::faults::FaultsConfig;
use solana_isp::metrics::Metrics;
use solana_isp::power::PowerModel;
use solana_isp::prop::{check, forall};
use solana_isp::traffic::{
    serve_fleet, AutoscaleConfig, AutoscalePolicy, LbPolicy, ServeReport, TrafficConfig,
};
use solana_isp::workloads::App;

fn serve(app: App, fcfg: &FleetConfig, tcfg: &TrafficConfig) -> ServeReport {
    let mut m = Metrics::new();
    serve_fleet(app, fcfg, tcfg, &PowerModel::default(), &mut m).expect("serve_fleet")
}

const APPS: [App; 3] = [App::SpeechToText, App::Recommender, App::Sentiment];
const SHAPES: [FleetShape; 3] = [FleetShape::AllCsd, FleetShape::AllSsd, FleetShape::Mixed];
const POLICIES: [LbPolicy; 4] = [
    LbPolicy::RoundRobin,
    LbPolicy::WeightedCapacity,
    LbPolicy::JoinShortestQueue,
    LbPolicy::LeastWork,
];

#[test]
fn quiet_fault_plan_is_bit_identical_to_no_plan() {
    // Randomized configs: app × shape × policy × resilience knobs. The
    // zero-rate plan arms the whole chaos path (plan construction,
    // fault stream forks, the tracking loop when resilience is on) yet
    // must never draw from the fault RNG or change a single event.
    forall("quiet faults == no faults", 10, |g| {
        let app = APPS[g.usize(0..=2)];
        let servers = g.usize(1..=3);
        let shape = SHAPES[g.usize(0..=2)];
        let policy = POLICIES[g.usize(0..=3)];
        let replicas = if servers > 1 && g.bool() { 1 } else { 0 };
        let fcfg = FleetConfig { servers, shape, replicas, ..FleetConfig::default() };
        let tcfg = TrafficConfig {
            load: g.f64(0.2, 0.8),
            requests: 400,
            policy,
            retries: g.u64(0..=3) as u32,
            hedge: g.bool(),
            ..TrafficConfig::default()
        };
        let clean = serve(app, &fcfg, &tcfg);
        let quiet =
            TrafficConfig { faults: Some(FaultsConfig::quiet()), ..tcfg.clone() };
        let faulted = serve(app, &fcfg, &quiet);
        clean.check_bit_identical(&faulted)
    });
}

#[test]
fn faulted_runs_with_same_seed_are_bit_identical() {
    // Heavy mixed fault plan — drive, server, and link faults all live
    // at once — run twice with the same seed: the virtual-time DES plus
    // per-component forked fault streams must reproduce every bit.
    for app in [App::SpeechToText, App::Sentiment] {
        let fcfg = FleetConfig {
            servers: 3,
            shape: FleetShape::Mixed,
            replicas: 1,
            ..FleetConfig::default()
        };
        let faults = FaultsConfig {
            seed: 42,
            ack_loss: 0.1,
            stall: 0.1,
            stall_s: 0.02,
            link_drop: 0.05,
            link_dup: 0.05,
            server_crash_at: Some(0.4),
            crash_server: 1,
            ..FaultsConfig::default()
        };
        let tcfg = TrafficConfig {
            load: 0.6,
            requests: 600,
            policy: LbPolicy::RoundRobin,
            retries: 3,
            hedge: true,
            faults: Some(faults),
            ..TrafficConfig::default()
        };
        let a = serve(app, &fcfg, &tcfg);
        let b = serve(app, &fcfg, &tcfg);
        a.check_bit_identical(&b).unwrap_or_else(|e| panic!("{app:?}: {e}"));
        assert_eq!(a.served + a.failed + a.shed, a.requests, "{app:?}: conservation");
    }
}

#[test]
fn conservation_holds_under_random_fault_plans() {
    forall("served + failed + shed == requests under chaos", 8, |g| {
        let app = APPS[g.usize(0..=2)];
        let servers = g.usize(1..=4);
        let shape = SHAPES[g.usize(0..=2)];
        let replicas = if servers > 1 && g.bool() { 1 } else { 0 };
        let faults = FaultsConfig {
            seed: g.u64(0..=u64::MAX / 2),
            ack_loss: g.f64(0.0, 0.15),
            stall: g.f64(0.0, 0.15),
            stall_s: g.f64(0.005, 0.05),
            link_drop: g.f64(0.0, 0.1),
            link_dup: g.f64(0.0, 0.1),
            server_crash_at: if g.bool() { Some(g.f64(0.1, 0.9)) } else { None },
            crash_server: g.usize(0..=servers - 1),
            ..FaultsConfig::default()
        };
        let fcfg = FleetConfig { servers, shape, replicas, ..FleetConfig::default() };
        let tcfg = TrafficConfig {
            load: g.f64(0.3, 0.9),
            requests: 400,
            policy: POLICIES[g.usize(0..=3)],
            retries: g.u64(0..=3) as u32,
            hedge: g.bool(),
            faults: Some(faults),
            ..TrafficConfig::default()
        };
        let r = serve(app, &fcfg, &tcfg);
        check(
            r.served + r.failed + r.shed == r.requests,
            format!(
                "served {} + failed {} + shed {} != requests {}",
                r.served, r.failed, r.shed, r.requests
            ),
        )?;
        check(
            (0.0..=1.0).contains(&r.availability),
            format!("availability out of range: {}", r.availability),
        )
    });
}

#[test]
fn disabled_autoscaler_is_bit_free() {
    // ISSUE-10 property 4: `autoscale: None` (the default) must take the
    // exact static serving path across apps × shapes × dispatch modes —
    // same bits on a rerun, inert elastic accounting, and
    // server-seconds exactly servers × duration (the bits the static
    // path computes, not a near-equal float).
    use solana_isp::sched::DispatchMode;
    forall("autoscale off == static path", 10, |g| {
        let app = APPS[g.usize(0..=2)];
        let servers = g.usize(1..=3);
        let shape = SHAPES[g.usize(0..=2)];
        let mut fcfg = FleetConfig { servers, shape, ..FleetConfig::default() };
        fcfg.sched.dispatch =
            if g.bool() { DispatchMode::EventDriven } else { DispatchMode::Polling };
        let tcfg = TrafficConfig {
            load: g.f64(0.2, 0.9),
            requests: 400,
            policy: POLICIES[g.usize(0..=3)],
            ..TrafficConfig::default()
        };
        let a = serve(app, &fcfg, &tcfg);
        let b = serve(app, &fcfg, &tcfg);
        a.check_bit_identical(&b)?;
        check(a.timeline.is_empty(), "static runs emit no fleet time series".to_string())?;
        check(
            a.joins == 0 && a.drains == 0 && a.migrations == 0 && a.migrated_bytes == 0,
            format!(
                "elastic counters must stay zero: joins {} drains {} migrations {}",
                a.joins, a.drains, a.migrations
            ),
        )?;
        check(
            a.peak_servers == servers,
            format!("peak {} != servers {servers}", a.peak_servers),
        )?;
        check(
            a.server_seconds.to_bits() == (servers as f64 * a.duration_secs).to_bits(),
            format!(
                "server-seconds must be exactly servers x duration: {} vs {}",
                a.server_seconds,
                servers as f64 * a.duration_secs
            ),
        )
    });
}

#[test]
fn conservation_survives_elastic_chaos() {
    // ISSUE-10 property 5: joins, drains, shard migrations, and a
    // mid-run server crash all at once — every request still accounted
    // for exactly once, no in-flight work lost at a drain, and the same
    // seed reproduces every bit.
    use solana_isp::traffic::fleet_nominal_rate;
    use solana_isp::workloads::AppModel;
    forall("conservation through joins/drains/migrations", 6, |g| {
        let app = APPS[g.usize(0..=2)];
        let servers = g.usize(2..=3);
        let shape = SHAPES[g.usize(0..=2)];
        let replicas = if g.bool() { 1 } else { 0 };
        let fcfg = FleetConfig { servers, shape, replicas, ..FleetConfig::default() };
        // Anchor the rate profile and the autoscaler clock to the
        // fleet's nominal rate so evaluations actually fire for every
        // app (absolute service rates span orders of magnitude).
        let model = AppModel::for_app(app, 1);
        let base = fleet_nominal_rate(&model, &fcfg.server_specs());
        let requests = 500u64;
        let dur = requests as f64 / base;
        let faults = FaultsConfig {
            seed: g.u64(0..=u64::MAX / 2),
            ack_loss: g.f64(0.0, 0.1),
            server_crash_at: Some(g.f64(0.2, 0.7)),
            crash_server: g.usize(0..=3),
            ..FaultsConfig::default()
        };
        let tcfg = TrafficConfig {
            rate_rps: Some(base),
            rate_segments: Some(vec![(0.3 * dur, 0.5), (0.2 * dur, 2.2), (0.5 * dur, 0.5)]),
            requests,
            policy: POLICIES[g.usize(0..=3)],
            skew: g.f64(0.0, 1.0),
            retries: g.u64(0..=2) as u32,
            hedge: g.bool(),
            faults: Some(faults),
            autoscale: Some(AutoscaleConfig {
                policy: if g.bool() {
                    AutoscalePolicy::Reactive
                } else {
                    AutoscalePolicy::Predictive
                },
                min_servers: 2,
                max_servers: 4,
                check_interval_s: dur / 24.0,
                estimator_window_s: dur / 6.0,
                shards: g.usize(4..=16),
                ..AutoscaleConfig::default()
            }),
            ..TrafficConfig::default()
        };
        let r = serve(app, &fcfg, &tcfg);
        check(
            r.served + r.failed + r.shed == r.requests,
            format!(
                "served {} + failed {} + shed {} != requests {}",
                r.served, r.failed, r.shed, r.requests
            ),
        )?;
        check(
            (0.0..=1.0).contains(&r.availability),
            format!("availability out of range: {}", r.availability),
        )?;
        check(
            !r.timeline.is_empty(),
            "the scaled eval clock must fire during the run".to_string(),
        )?;
        let again = serve(app, &fcfg, &tcfg);
        r.check_bit_identical(&again)
    });
}
