//! Host↔CSD interconnects: the NVMe-over-PCIe link (path "a") and the
//! TCP/IP tunnel over PCIe/NVMe (path "c") from Fig. 4 of the paper.
//!
//! The paper's §IV-A quantifies the asymmetry this module models:
//! "all nodes access the data at a much higher speed (GBps of PCIe/NVMe
//! for the host and DMA/hardware for the in-situ vs. MBps of TCP/IP)" —
//! which is precisely why the scheduler ships *indexes* over the tunnel
//! and lets data move through the shared file system.

pub mod tunnel_proto;

use crate::sim::{Pipe, SimTime, Transfer};

/// Constructor guard shared by every link type (ISSUE-6 satellite): a
/// non-positive or non-finite bandwidth / negative or non-finite
/// overhead silently produces NaN or infinite transfer times that
/// poison every downstream latency figure, so reject loudly at the
/// construction site instead.
fn validate_link(kind: &str, bandwidth: f64, overhead: SimTime) {
    assert!(
        bandwidth > 0.0 && bandwidth.is_finite(),
        "{kind}: bandwidth must be positive and finite, got {bandwidth}"
    );
    assert!(
        overhead >= 0.0 && overhead.is_finite(),
        "{kind}: per-message overhead must be non-negative and finite, got {overhead}"
    );
}

/// NVMe over 4-lane PCIe Gen3: ~3.2 GB/s usable per drive after 128b/130b
/// and protocol overhead; ~10 µs command round-trip.
#[derive(Debug, Clone)]
pub struct PcieLink {
    pipe: Pipe,
    /// NVMe submission→completion fixed overhead per command (s).
    pub cmd_overhead: SimTime,
}

impl Default for PcieLink {
    fn default() -> Self {
        PcieLink::new(3.2e9, 10e-6)
    }
}

impl PcieLink {
    pub fn new(bandwidth: f64, cmd_overhead: SimTime) -> PcieLink {
        validate_link("PcieLink", bandwidth, cmd_overhead);
        PcieLink { pipe: Pipe::new(bandwidth, 0.0), cmd_overhead }
    }

    /// Move `bytes` across the link as one NVMe command at `now`.
    pub fn dma(&mut self, now: SimTime, bytes: u64) -> Transfer {
        self.pipe.transfer(now + self.cmd_overhead, bytes)
    }

    pub fn bytes_moved(&self) -> u64 {
        self.pipe.bytes_moved()
    }

    pub fn transfers(&self) -> u64 {
        self.pipe.transfers()
    }

    pub fn busy_secs(&self) -> f64 {
        self.pipe.busy_secs()
    }

    pub fn unloaded_secs(&self, bytes: u64) -> SimTime {
        self.cmd_overhead + self.pipe.unloaded_secs(bytes)
    }
}

/// The TCP/IP tunnel over PCIe/NVMe (§III-C3): two user-level daemons
/// encapsulate TCP segments into NVMe vendor commands through a pair of
/// shared DRAM ring buffers. Orders of magnitude slower than the raw
/// link — per-message user-space encapsulation dominates.
#[derive(Debug, Clone)]
pub struct TcpTunnel {
    pipe: Pipe,
    /// Per-message encapsulation/decapsulation cost (user-level daemons
    /// on both ends + NVMe doorbell), seconds.
    pub msg_overhead: SimTime,
    messages: u64,
    async_bytes: u64,
}

impl Default for TcpTunnel {
    fn default() -> Self {
        // ~120 MB/s sustained, ~150 µs per message round trip cost.
        TcpTunnel::new(120e6, 150e-6)
    }
}

impl TcpTunnel {
    pub fn new(bandwidth: f64, msg_overhead: SimTime) -> TcpTunnel {
        validate_link("TcpTunnel", bandwidth, msg_overhead);
        TcpTunnel { pipe: Pipe::new(bandwidth, 0.0), msg_overhead, messages: 0, async_bytes: 0 }
    }

    /// Send one message of `bytes` at `now`; returns delivery time at the
    /// far end.
    pub fn send(&mut self, now: SimTime, bytes: u64) -> SimTime {
        self.messages += 1;
        self.pipe.transfer(now + self.msg_overhead, bytes).end
    }

    /// Fire-and-forget message at a (possibly future) time: counts
    /// traffic and returns the unloaded delivery time *without* holding
    /// the pipe's FIFO horizon. Used for scheduler dispatch/ack messages
    /// whose send times are computed ahead of the simulation cursor —
    /// reserving the pipe for them would make earlier DLM traffic queue
    /// behind the future (a pure artifact of analytic scheduling; the
    /// real tunnel is idle in between).
    pub fn send_async(&mut self, at: SimTime, bytes: u64) -> SimTime {
        self.messages += 1;
        self.async_bytes += bytes;
        at + self.msg_overhead + bytes as f64 / self.pipe.bandwidth
    }

    /// A request/response exchange (e.g. a DLM lock grant): two messages.
    pub fn round_trip(&mut self, now: SimTime, req_bytes: u64, resp_bytes: u64) -> SimTime {
        let t = self.send(now, req_bytes);
        self.send(t, resp_bytes)
    }

    pub fn messages(&self) -> u64 {
        self.messages
    }

    pub fn bytes_moved(&self) -> u64 {
        self.pipe.bytes_moved() + self.async_bytes
    }

    pub fn busy_secs(&self) -> f64 {
        self.pipe.busy_secs()
    }

    pub fn unloaded_secs(&self, bytes: u64) -> SimTime {
        self.msg_overhead + self.pipe.unloaded_secs(bytes)
    }
}

/// Default rack-link parameters (one 10 GbE port: ~1.25 GB/s usable
/// after framing, ~50 µs per message for NIC + switch + kernel path),
/// shared with [`crate::cluster::fleet::FleetConfig`] so the two
/// defaults cannot drift apart.
pub const RACK_BANDWIDTH: f64 = 1.25e9;
pub const RACK_MSG_OVERHEAD: SimTime = 50e-6;

/// Top-of-rack aggregation link (the fleet layer's cross-server path).
///
/// When a [`crate::cluster::fleet`] run finishes its per-server phase,
/// every non-head server ships its result block to the head server for
/// the cross-server aggregation/merge. Each server's uplink into the
/// rack switch is uncontended, but the head's single downlink is
/// shared, so result transfers serialize FIFO there — that is the pipe
/// this type models. A rack link *is* a message link with different
/// physics (switched Ethernet port instead of the in-box NVMe tunnel),
/// so it composes [`TcpTunnel`]'s pipe + per-message-overhead
/// accounting rather than re-implementing it.
#[derive(Debug, Clone)]
pub struct RackLink {
    link: TcpTunnel,
}

impl Default for RackLink {
    fn default() -> Self {
        RackLink::new(RACK_BANDWIDTH, RACK_MSG_OVERHEAD)
    }
}

impl RackLink {
    pub fn new(bandwidth: f64, msg_overhead: SimTime) -> RackLink {
        // TcpTunnel::new validates, but assert here too so the panic
        // message names the type the caller actually constructed.
        validate_link("RackLink", bandwidth, msg_overhead);
        RackLink { link: TcpTunnel::new(bandwidth, msg_overhead) }
    }

    /// Deliver one result block of `bytes` entering the head's downlink
    /// at `now`; returns completion time. Concurrent blocks queue behind
    /// the link's busy horizon (FIFO).
    pub fn send(&mut self, now: SimTime, bytes: u64) -> SimTime {
        self.link.send(now, bytes)
    }

    pub fn messages(&self) -> u64 {
        self.link.messages()
    }

    pub fn bytes_moved(&self) -> u64 {
        self.link.bytes_moved()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcie_vs_tunnel_asymmetry() {
        // The design point from §IV-A: bulk data over PCIe is ~GB/s, the
        // tunnel is ~MB/s. Moving 1 MiB must be >20x faster on PCIe.
        let mut pcie = PcieLink::default();
        let mut tun = TcpTunnel::default();
        let p = pcie.dma(0.0, 1 << 20);
        let t = tun.send(0.0, 1 << 20);
        assert!(t > 20.0 * p.end, "tunnel {t} vs pcie {}", p.end);
    }

    #[test]
    fn small_message_dominated_by_overhead() {
        let mut tun = TcpTunnel::default();
        let t = tun.send(0.0, 64); // an ack
        assert!((t - (150e-6 + 64.0 / 120e6)).abs() < 1e-9);
    }

    #[test]
    fn round_trip_is_two_messages() {
        let mut tun = TcpTunnel::default();
        let t = tun.round_trip(0.0, 64, 64);
        assert_eq!(tun.messages(), 2);
        assert!(t > 2.0 * 150e-6);
    }

    #[test]
    fn rack_link_serializes_result_blocks() {
        // Two 1.25 MB result blocks entering the head's downlink at the
        // same instant: the second waits for the first (FIFO pipe).
        let mut rack = RackLink::new(1.25e9, 0.0);
        let a = rack.send(0.0, 1_250_000);
        let b = rack.send(0.0, 1_250_000);
        assert!((a - 1e-3).abs() < 1e-9, "first block {a}");
        assert!((b - 2e-3).abs() < 1e-9, "second block queues: {b}");
        assert_eq!(rack.messages(), 2);
        assert_eq!(rack.bytes_moved(), 2_500_000);
    }

    #[test]
    fn rack_link_small_message_dominated_by_overhead() {
        let mut rack = RackLink::default();
        let t = rack.send(0.0, 64);
        assert!((t - (50e-6 + 64.0 / 1.25e9)).abs() < 1e-12, "{t}");
    }

    // ---- ISSUE-6 satellite: constructors reject nonsense params -----

    #[test]
    #[should_panic(expected = "bandwidth must be positive")]
    fn pcie_rejects_zero_bandwidth() {
        let _ = PcieLink::new(0.0, 10e-6);
    }

    #[test]
    #[should_panic(expected = "bandwidth must be positive")]
    fn tunnel_rejects_negative_bandwidth() {
        let _ = TcpTunnel::new(-1.0, 150e-6);
    }

    #[test]
    #[should_panic(expected = "bandwidth must be positive")]
    fn rack_rejects_nan_bandwidth() {
        let _ = RackLink::new(f64::NAN, 50e-6);
    }

    #[test]
    #[should_panic(expected = "overhead must be non-negative")]
    fn pcie_rejects_negative_overhead() {
        let _ = PcieLink::new(3.2e9, -1e-6);
    }

    #[test]
    #[should_panic(expected = "overhead must be non-negative")]
    fn tunnel_rejects_infinite_overhead() {
        let _ = TcpTunnel::new(120e6, f64::INFINITY);
    }

    #[test]
    #[should_panic(expected = "overhead must be non-negative")]
    fn rack_rejects_nan_overhead() {
        let _ = RackLink::new(1.25e9, f64::NAN);
    }

    #[test]
    fn zero_overhead_remains_valid() {
        // Tests and analytic callers use overhead-free links; the guard
        // must not outlaw them.
        let _ = PcieLink::new(1e9, 0.0);
        let _ = TcpTunnel::new(1e9, 0.0);
        let _ = RackLink::new(1e9, 0.0);
    }

    #[test]
    fn pcie_serializes_commands() {
        let mut pcie = PcieLink::new(1e9, 0.0);
        let a = pcie.dma(0.0, 1_000_000); // 1 ms
        let b = pcie.dma(0.0, 1_000_000);
        assert!((a.end - 1e-3).abs() < 1e-9);
        assert!((b.end - 2e-3).abs() < 1e-9);
        assert_eq!(pcie.bytes_moved(), 2_000_000);
    }
}
