// Negative fixture for D3 rng-gate: a file-wide suppression with a
// reason covers every draw in the file.
// solana-lint: allow-file(rng-gate, reason = "fixture: whole-file suppression")

pub fn draw(rng: &mut Rng) -> f64 {
    rng.exponential(1.0)
}
