// Positive fixture (ISSUE-9): the two determinism hazards a span
// tracer is most tempted by — stamping spans off wall clocks instead of
// simulated time, and draining a hash-ordered span map into an export.
use std::collections::HashMap;
use std::time::{Instant, SystemTime};

pub struct Span {
    pub t0: f64,
    pub t1: f64,
}

pub fn stamp_span() -> (Instant, SystemTime) {
    let t0 = Instant::now();
    let wall = SystemTime::now();
    (t0, wall)
}

pub fn export_spans() -> Vec<f64> {
    let mut spans: HashMap<u64, Span> = HashMap::new();
    spans.insert(7, Span { t0: 0.0, t1: 1.5 });
    let mut out = Vec::new();
    for s in spans.values() {
        out.push(s.t1 - s.t0);
    }
    out
}
