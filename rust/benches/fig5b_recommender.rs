//! `cargo bench --bench fig5b_recommender` — regenerates Fig 5(b): recommender throughput sweep
//!
//! Scale with `SOLANA_BENCH_FAST=1` (5%) or default 25% of the paper's
//! dataset sizes; the *shape* (who wins, by what factor, where the
//! crossovers fall) is scale-invariant. See EXPERIMENTS.md.

use solana_isp::bench_support::Bencher;
use solana_isp::exp::{self, Scale};
#[allow(unused_imports)]
use solana_isp::workloads::App;

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let table = exp::fig5(App::Recommender, scale)?;
    exp::emit(&table, "fig5b")?;
    // Wall-time of regenerating the artifact (simulator throughput):
    let mut b = Bencher::new(0, if std::env::var("SOLANA_BENCH_FAST").is_ok() { 1 } else { 2 });
    b.bench("fig5b_recommender", || {
        let t = exp::fig5(App::Recommender, scale).expect("rerun");
        t.rows.len() as u64
    });
    print!("{}", b.report());
    b.write_json("fig5b_recommender")?;
    Ok(())
}
