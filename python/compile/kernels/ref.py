"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas outputs match these to float tolerance.
Keep them boring and obviously correct.
"""

import jax.numpy as jnp


def matmul(x, w, b=None):
    """Reference for kernels.matmul: x @ w (+ b)."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y


def similarity(m, q):
    """Reference for kernels.similarity: row-wise dot scores M @ q."""
    return jnp.dot(m.astype(jnp.float32), q.astype(jnp.float32))


def cosine_scores(m, q, eps=1e-8):
    """Full cosine similarity (normalizes both sides)."""
    mn = m / (jnp.linalg.norm(m, axis=1, keepdims=True) + eps)
    qn = q / (jnp.linalg.norm(q) + eps)
    return jnp.dot(mn, qn)
