"""L2 correctness: benchmark model graphs (shapes, semantics, training)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def test_sentiment_infer_shapes_and_range():
    rng = np.random.default_rng(0)
    x = rng.random((32, model.SENT_FEATURES)).astype(np.float32)
    w = (0.01 * rng.standard_normal((model.SENT_FEATURES, 1))).astype(np.float32)
    b = np.zeros(1, np.float32)
    (p,) = model.sentiment_infer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    assert p.shape == (32,)
    assert float(p.min()) >= 0.0 and float(p.max()) <= 1.0


def test_sentiment_train_step_reduces_loss_on_separable_data():
    rng = np.random.default_rng(1)
    bsz, f = model.SENT_TRAIN_BATCH, model.SENT_FEATURES
    # separable: positive rows load bucket 0, negative rows bucket 1
    y = (rng.random(bsz) < 0.5).astype(np.float32)
    x = np.zeros((bsz, f), np.float32)
    x[y == 1.0, 0] = 1.0
    x[y == 0.0, 1] = 1.0
    w = jnp.zeros((f, 1), jnp.float32)
    b = jnp.zeros(1, jnp.float32)
    losses = []
    for _ in range(30):
        w, b, loss = model.sentiment_train_step(
            jnp.asarray(x), jnp.asarray(y), w, b, jnp.float32(5.0))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses[::10]
    (p,) = model.sentiment_infer(jnp.asarray(x), w, b)
    acc = float(np.mean((np.asarray(p) > 0.5) == (y == 1.0)))
    assert acc > 0.95


def test_sentiment_gradient_matches_autodiff():
    """The hand-derived closed-form gradient must equal jax.grad."""
    rng = np.random.default_rng(2)
    bsz, f = 8, 32

    def loss_fn(w, b, x, y):
        logits = x @ w[:, 0] + b[0]
        p = jax.nn.sigmoid(logits)
        eps = 1e-7
        return -jnp.mean(y * jnp.log(p + eps) + (1 - y) * jnp.log(1 - p + eps))

    x = rng.standard_normal((bsz, f)).astype(np.float32)
    y = (rng.random(bsz) < 0.5).astype(np.float32)
    w = rng.standard_normal((f, 1)).astype(np.float32) * 0.1
    b = np.zeros(1, np.float32)
    gw, gb = jax.grad(loss_fn, argnums=(0, 1))(
        jnp.asarray(w), jnp.asarray(b), jnp.asarray(x), jnp.asarray(y))
    lr = 0.7
    w2, b2, _ = model.sentiment_train_step(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(w), jnp.asarray(b),
        jnp.float32(lr))
    np.testing.assert_allclose(np.asarray(w2), w - lr * np.asarray(gw),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(b2), b - lr * np.asarray(gb),
                               rtol=1e-4, atol=1e-5)


def _unit_rows(x, eps=1e-8):
    return x / (np.linalg.norm(x, axis=-1, keepdims=True) + eps)


def test_recommender_topk_finds_self():
    rng = np.random.default_rng(3)
    n, d = 500, model.REC_DIM  # small catalogue for the test
    m = _unit_rows(rng.standard_normal((n, d)).astype(np.float32))
    pop = np.ones(n, np.float32)
    q = m[[42, 7]]
    vals, idx = model.recommender_topk(
        jnp.asarray(m), jnp.asarray(pop), jnp.asarray(q))
    assert vals.shape == (2, model.REC_TOPK)
    assert idx.shape == (2, model.REC_TOPK)
    assert int(idx[0, 0]) == 42
    assert int(idx[1, 0]) == 7
    # scores sorted descending
    v = np.asarray(vals)
    assert (np.diff(v, axis=1) <= 1e-6).all()


def test_recommender_popularity_blend_reorders():
    rng = np.random.default_rng(4)
    n, d = 50, model.REC_DIM
    m = _unit_rows(np.abs(rng.standard_normal((n, d))).astype(np.float32))
    q = m[[0]]
    # no popularity: some ranking
    pop0 = np.zeros(n, np.float32)
    _, idx0 = model.recommender_topk(jnp.asarray(m), jnp.asarray(pop0), jnp.asarray(q))
    # boost one mid-ranked item to max popularity
    boosted = int(np.asarray(idx0)[0, 5])
    pop1 = np.zeros(n, np.float32)
    pop1[boosted] = 1.0
    _, idx1 = model.recommender_topk(jnp.asarray(m), jnp.asarray(pop1), jnp.asarray(q))
    r0 = list(np.asarray(idx0)[0]).index(boosted)
    r1 = list(np.asarray(idx1)[0]).index(boosted)
    assert r1 < r0, f"popularity boost should improve rank ({r0} -> {r1})"


def test_acoustic_forward_is_log_distribution():
    rng = np.random.default_rng(5)
    shapes = model.acoustic_param_shapes()
    params = [
        (0.1 * rng.standard_normal(shapes[k])).astype(np.float32)
        for k in ("w1", "b1", "w2", "b2", "w3", "b3")
    ]
    frames = rng.standard_normal(
        (model.SPEECH_FRAMES, model.SPEECH_FEATURES)).astype(np.float32)
    (lp,) = model.acoustic_forward(jnp.asarray(frames),
                                   *[jnp.asarray(p) for p in params])
    assert lp.shape == (model.SPEECH_FRAMES, model.SPEECH_VOCAB)
    # each row sums to 1 in prob space
    np.testing.assert_allclose(np.exp(np.asarray(lp)).sum(axis=1),
                               np.ones(model.SPEECH_FRAMES), rtol=1e-4)


def test_acoustic_is_deterministic():
    rng = np.random.default_rng(6)
    shapes = model.acoustic_param_shapes()
    params = [jnp.asarray((0.1 * rng.standard_normal(shapes[k])).astype(np.float32))
              for k in ("w1", "b1", "w2", "b2", "w3", "b3")]
    frames = jnp.asarray(rng.standard_normal(
        (model.SPEECH_FRAMES, model.SPEECH_FEATURES)).astype(np.float32))
    (a,) = model.acoustic_forward(frames, *params)
    (b,) = model.acoustic_forward(frames, *params)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
