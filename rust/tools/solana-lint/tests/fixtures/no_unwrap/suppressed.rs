// Negative fixture for D4 no-unwrap: a reasoned marker suppresses a
// genuinely-infallible site.
pub fn first(v: &[u64]) -> u64 {
    // solana-lint: allow(no-unwrap, reason = "fixture: caller guarantees non-empty")
    *v.first().unwrap()
}
