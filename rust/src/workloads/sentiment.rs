//! Sentiment analysis benchmark (§IV-B3): train a binary classifier on
//! labeled tweets, then serve predictions. Training and inference both
//! run through AOT executables (`sentiment_train_step`, `sentiment_infer`)
//! on the PJRT runtime — the same binary the ISP engines execute in the
//! simulated cluster.

use crate::nlp::corpus::Tweet;
use crate::nlp::HashingVectorizer;
use crate::runtime::{Engine, Tensor};
use crate::util::Rng;

/// Trained sentiment model + featurizer.
pub struct SentimentApp {
    pub vectorizer: HashingVectorizer,
    pub w: Tensor,
    pub b: Tensor,
    features: usize,
    train_batch: usize,
}

impl SentimentApp {
    /// Assemble an app from pre-trained weights (e.g. received over the
    /// live cluster's weight broadcast).
    pub fn from_weights(features: usize, w: Tensor, b: Tensor) -> SentimentApp {
        assert_eq!(w.shape, vec![features, 1]);
        assert_eq!(b.shape, vec![1]);
        SentimentApp {
            vectorizer: HashingVectorizer::new(features),
            w,
            b,
            features,
            train_batch: 256,
        }
    }

    /// Train on `tweets` for `epochs` passes of SGD (batch 256, lr
    /// decayed per epoch). Returns the fitted app and the loss curve.
    pub fn train(
        eng: &mut Engine,
        tweets: &[Tweet],
        epochs: usize,
        seed: u64,
    ) -> anyhow::Result<(SentimentApp, Vec<f32>)> {
        let f = eng.manifest.dim("sent_features")? as usize;
        let bt = eng.manifest.dim("sent_train_batch")? as usize;
        let vectorizer = HashingVectorizer::new(f);
        let mut w = Tensor::zeros(vec![f, 1]);
        let mut b = Tensor::zeros(vec![1]);
        let mut order: Vec<usize> = (0..tweets.len()).collect();
        let mut rng = Rng::new(seed);
        let mut losses = Vec::new();
        let variant = format!("b{bt}");
        let mut x = Tensor::zeros(vec![bt, f]);
        let mut y = Tensor::zeros(vec![bt]);
        for epoch in 0..epochs {
            rng.shuffle(&mut order);
            let lr = Tensor::scalar(12.0 / (1.0 + 0.5 * epoch as f32));
            for chunk in order.chunks_exact(bt) {
                for (row, &ti) in chunk.iter().enumerate() {
                    let t = &tweets[ti];
                    vectorizer.vectorize_into(&t.text, &mut x.data[row * f..(row + 1) * f]);
                    y.data[row] = if t.positive { 1.0 } else { 0.0 };
                }
                let out = eng.run(
                    "sentiment_train_step",
                    &variant,
                    &[x.clone(), y.clone(), w, b, lr.clone()],
                )?;
                let mut it = out.into_iter();
                match (it.next(), it.next(), it.next()) {
                    (Some(new_w), Some(new_b), Some(loss)) => {
                        w = new_w;
                        b = new_b;
                        losses.push(loss.data[0]);
                    }
                    _ => anyhow::bail!(
                        "sentiment_train_step returned fewer than 3 outputs (w, b, loss)"
                    ),
                }
            }
        }
        Ok((
            SentimentApp { vectorizer, w, b, features: f, train_batch: bt },
            losses,
        ))
    }

    /// Classify a batch of texts; pads the final chunk to the AOT batch
    /// shape. Returns P(positive) per text.
    pub fn predict(&self, eng: &mut Engine, texts: &[&str]) -> anyhow::Result<Vec<f32>> {
        let f = self.features;
        let b = 32usize; // serving variant
        let mut probs = Vec::with_capacity(texts.len());
        let mut x = Tensor::zeros(vec![b, f]);
        for chunk in texts.chunks(b) {
            for (row, text) in chunk.iter().enumerate() {
                self.vectorizer
                    .vectorize_into(text, &mut x.data[row * f..(row + 1) * f]);
            }
            for row in chunk.len()..b {
                x.data[row * f..(row + 1) * f].fill(0.0);
            }
            let out = eng.run(
                "sentiment_infer",
                "b32",
                &[x.clone(), self.w.clone(), self.b.clone()],
            )?;
            probs.extend_from_slice(&out[0].data[..chunk.len()]);
        }
        Ok(probs)
    }

    /// Accuracy over labeled tweets.
    pub fn accuracy(&self, eng: &mut Engine, tweets: &[Tweet]) -> anyhow::Result<f64> {
        let texts: Vec<&str> = tweets.iter().map(|t| t.text.as_str()).collect();
        let probs = self.predict(eng, &texts)?;
        let correct = probs
            .iter()
            .zip(tweets)
            .filter(|(p, t)| (**p > 0.5) == t.positive)
            .count();
        Ok(correct as f64 / tweets.len() as f64)
    }

    pub fn train_batch(&self) -> usize {
        self.train_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nlp::corpus::TweetCorpus;

    #[test]
    fn trains_to_high_accuracy_and_serves() {
        let Some(mut eng) = Engine::load_default() else { return };
        let mut corpus = TweetCorpus::new(11);
        let train = corpus.take(2048);
        let test = corpus.take(512);
        let (app, losses) = SentimentApp::train(&mut eng, &train, 4, 5).unwrap();
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "loss fell: {:?} -> {:?}",
            losses.first(),
            losses.last()
        );
        let acc = app.accuracy(&mut eng, &test).unwrap();
        assert!(acc > 0.85, "test accuracy {acc}");
        // ragged batch: predict a non-multiple-of-32 count
        let texts: Vec<&str> = test[..37].iter().map(|t| t.text.as_str()).collect();
        let probs = app.predict(&mut eng, &texts).unwrap();
        assert_eq!(probs.len(), 37);
        assert!(probs.iter().all(|p| (0.0..=1.0).contains(p)));
    }
}
