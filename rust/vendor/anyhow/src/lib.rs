//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored
//! member provides the exact subset `solana-isp` uses: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the
//! [`Context`] extension trait. Semantics match upstream where it
//! matters here:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! * `{}` displays the outermost message, `{:#}` the full cause chain
//!   joined with `": "` (what `eprintln!("error: {e:#}")` relies on);
//! * `Error` itself does **not** implement `std::error::Error`, which is
//!   what makes the blanket `From` impl coherent — same trick as the
//!   real crate.

use std::fmt;

/// Error: an ordered cause chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message (what `anyhow!` emits).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Prepend a context layer (outermost position).
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attaching extension for `Result` and `Option` (the upstream
/// `anyhow::Context` surface used by this workspace).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::Error::msg(format!($($arg)+)))
    };
}

/// Return early with an error when the condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)+)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing file"));
    }

    #[test]
    fn alternate_display_shows_chain() {
        let e: Result<()> = Err(io_err()).with_context(|| "reading manifest".to_string());
        let msg = format!("{:#}", e.unwrap_err());
        assert!(msg.starts_with("reading manifest: "), "{msg}");
        assert!(msg.contains("missing file"), "{msg}");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u64) -> Result<u64> {
            ensure!(x > 2, "too small: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(10).unwrap(), 10);
        assert_eq!(f(1).unwrap_err().to_string(), "too small: 1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
    }
}
