//! The paper's distributed scheduler (§IV-A) and its simulation runner.
//!
//! Mechanism (faithful to the paper):
//!
//! * **pull-based**: each node (host, CSD ISPs) sends an *ack* when its
//!   current batch finishes, which doubles as the request for the next
//!   one;
//! * **polling loop**: the scheduler thread wakes every 0.2 s, drains
//!   pending acks, and dispatches new batches — sleeping between wakes
//!   releases the host CPU (the paper's stated reason for the design);
//! * **index-only dispatch**: because host and ISP mount the same OCFS2
//!   partition, the scheduler ships only item *indexes* over the TCP/IP
//!   tunnel; data moves over the fast paths (PCIe for the host,
//!   intra-chip DMA for the ISP);
//! * **batch ratio**: the host gets `ratio ×` the CSD batch size to match
//!   its Xeon-vs-A53 speed advantage (§IV-A: "ranging from 20 to 30");
//!   any other ratio under-utilizes one side (ablation A1).
//!
//! The runner executes this protocol in virtual time against the full
//! device models in [`crate::cluster`] and reports the quantities the
//! paper's figures plot.
//!
//! # Dispatch modes (ablation A4)
//!
//! The polling loop is a *design choice*, not a necessity, and
//! [`DispatchMode`] makes it a config axis:
//!
//! * [`DispatchMode::Polling`] (default) is the paper's scheduler,
//!   bit-identical to every previous release, governed by
//!   [`SchedConfig::wakeup_secs`] and [`SchedConfig::coalesce_wakes`].
//! * [`DispatchMode::EventDriven`] dispatches a node's next batch the
//!   moment its ack pops — off-grid, reactively — which removes the mean
//!   half-period idle gap every batch otherwise pays waiting for the
//!   next grid point. The host- and CSD-dispatch bodies are shared
//!   routines ([`SchedState::dispatch_host`] /
//!   [`SchedState::dispatch_csds`]) called from the `Wake` arm in
//!   polling mode and from the ack arms in event-driven mode, so the two
//!   modes differ only in *when* dispatch runs, never in *what* it does.
//!
//! Ablation A4 ([`crate::exp::ablate_dispatch`], `solana ablate --which
//! dispatch`) quantifies what the polling design costs: the gap is
//! largest at small batch sizes, where the half-period idle dominates
//! the per-batch service time. The property tests below assert that
//! event-driven conserves items and never yields a longer makespan.
//!
//! # The wake-grid invariant and wake coalescing (polling mode)
//!
//! Dispatch decisions happen **only** at points of the wake grid
//! `t0 + k·wakeup_secs` (`t0` = ingest completion): acks mutate node
//! state when they pop, but work is handed out exclusively by `Wake`
//! events, and every wake is scheduled a whole number of periods after
//! the previous one. Two consequences the fast path exploits:
//!
//! 1. A completed wake leaves nothing dispatchable — an idle node with
//!    reachable work is always given a batch during the wake — so every
//!    grid point strictly before the next pending ack is a *no-op* wake.
//!    With `coalesce_wakes` (default on) the runner skips those no-op
//!    grid points: it peeks the earliest pending event
//!    ([`EventQueue::peek_time`]) and schedules the next wake at the
//!    first grid point at or after it, walking the grid with the same
//!    float additions the naive chain performs so executed wakes keep
//!    **bit-identical** timestamps.
//! 2. CSD acks dispatched by one wake whose delivery times are
//!    bit-identical (lockstep drives are the common case) are batched
//!    into a single calendar entry, processed in dispatch order —
//!    exactly the order the separate entries would pop in.
//!
//! Both transformations change only the number of events executed
//! ([`RunReport::events_executed`], [`RunReport::wake_events`]); every
//! other field of [`RunReport`] is bit-identical with coalescing on or
//! off. Ablation A3 ([`crate::exp::ablate_wakeup`]) and the property
//! test below compare the two modes.

pub mod live;
pub mod locality;

use crate::cluster::StorageServer;
use crate::csd::CsdConfig;
use crate::metrics::{HistogramId, Metrics};
use crate::power::PowerModel;
use crate::sim::EventQueue;
use crate::workloads::{AppModel, HOST_THREADS, ISP_CORES};

/// How the scheduler hands out batches (the ISSUE-2 tentpole; ablation
/// A4 quantifies the difference).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DispatchMode {
    /// The paper's design (§IV-A): dispatch happens **only** at
    /// wake-grid points `t0 + k·wakeup_secs`, parameterized by
    /// [`SchedConfig::wakeup_secs`] and [`SchedConfig::coalesce_wakes`].
    /// Default — today's behavior, bit-identical to previous releases.
    #[default]
    Polling,
    /// Reactive dispatch: a node is handed its next batch the moment its
    /// ack pops (off-grid). The wake grid disappears — a single
    /// bootstrap wake at `t0` starts the run, so
    /// [`RunReport::wake_events`] is 1 — and `wakeup_secs` /
    /// `coalesce_wakes` are ignored. Every dispatch happens at or before
    /// the grid point the polling scheduler would have used, removing
    /// the mean half-period idle gap each batch otherwise pays; the
    /// effect is largest at small batches (A4). With the fair-share
    /// tail (`fair_tail`, the default) event-driven is never slower
    /// than polling — the property tests assert it; under the paper's
    /// plain tail, dispatch timing can reassign a whole tail batch
    /// between host and CSD in either direction (see the property
    /// test's scope note). This is the reactive, request-driven offload
    /// path the CSD literature argues for (ZCSD; Lukken & Trivedi's
    /// survey names dispatch latency as a recurring CSD bottleneck).
    EventDriven,
}

impl DispatchMode {
    /// Stable lowercase name used by the CLI, TOML configs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            DispatchMode::Polling => "polling",
            DispatchMode::EventDriven => "event-driven",
        }
    }
}

/// Scheduler configuration for one run.
#[derive(Clone, Debug)]
pub struct SchedConfig {
    /// Items per CSD batch (the paper's "batch size").
    pub csd_batch: u64,
    /// Host batch = `ratio × csd_batch` (the paper's "batch ratio").
    pub batch_ratio: f64,
    /// Scheduler polling period (paper: 0.2 s). Polling mode only.
    pub wakeup_secs: f64,
    /// Populated drive bays (data is striped over all of them).
    pub drives: usize,
    /// How many of those drives have their ISP engine engaged
    /// (Fig 5's x-axis). `0` = the paper's baseline: CSDs act as
    /// storage only.
    pub isp_drives: usize,
    /// Host participates in compute (always true in the paper).
    pub use_host: bool,
    /// Fair-share tail shrinking (our improvement over the paper's
    /// scheduler): near the end of the run the host's batch shrinks to
    /// its fair share so host and CSDs finish together. Disable to get
    /// the paper's plain behaviour (ablation A1 shows the difference).
    pub fair_tail: bool,
    /// Skip no-op polling wakes (and batch same-timestamp CSD acks)
    /// by jumping to the next wake-grid point at or after the earliest
    /// pending ack. Simulated results are bit-identical either way — see
    /// the module docs — only `events_executed`/`wake_events` change.
    /// Default on; turn off for the faithful-naive baseline (A3).
    /// Polling mode only.
    pub coalesce_wakes: bool,
    /// When batches are handed out: the paper's polling grid (default)
    /// or reactively on ack arrival. See [`DispatchMode`] and A4.
    pub dispatch: DispatchMode,
    /// Deterministic seed (shard layout etc.).
    pub seed: u64,
    /// Per-drive device model (flash geometry, ZNS / background-GC
    /// modes, ISP engine). Defaults to the paper's 12-TB prototype;
    /// fig13 shrinks the geometry so GC fires within a serving run.
    pub csd: CsdConfig,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            csd_batch: 6,
            batch_ratio: 20.0,
            wakeup_secs: 0.2,
            drives: 36,
            isp_drives: 36,
            use_host: true,
            fair_tail: true,
            coalesce_wakes: true,
            dispatch: DispatchMode::Polling,
            seed: 42,
            csd: CsdConfig::default(),
        }
    }
}

impl SchedConfig {
    /// The host-only baseline the paper compares against (drives
    /// populated, every ISP disabled).
    pub fn baseline(drives: usize) -> SchedConfig {
        SchedConfig { isp_drives: 0, drives, ..SchedConfig::default() }
    }

    pub fn use_isp(&self) -> bool {
        self.isp_drives > 0
    }

    pub fn host_batch(&self) -> u64 {
        ((self.csd_batch as f64 * self.batch_ratio).round() as u64).max(1)
    }
}

/// Everything a run produces; feeds every figure/table in the paper.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub app: &'static str,
    /// [`DispatchMode::name`] of the mode that produced this report.
    pub dispatch: &'static str,
    pub total_items: u64,
    pub makespan_secs: f64,
    pub items_per_sec: f64,
    /// Speech reports words/s (items/s × words per item).
    pub words_per_sec: f64,
    pub host_items: u64,
    pub csd_items: u64,
    /// Bytes that crossed PCIe into host memory.
    pub pcie_bytes: u64,
    /// Bytes served to ISP engines without leaving the drives.
    pub isp_bytes: u64,
    /// Result/ack/dispatch traffic over the tunnels.
    pub tunnel_messages: u64,
    pub energy_j: f64,
    pub avg_power_w: f64,
    pub energy_per_item_j: f64,
    pub host_busy_secs: f64,
    pub isp_busy_secs: f64,
    /// Mean batch latency (dispatch → ack), seconds.
    pub mean_batch_latency: f64,
    pub host_batches: u64,
    pub csd_batches: u64,
    /// Total DES calendar events executed for this run (acks + wakes).
    /// Wake coalescing drives this down; every other field is unchanged.
    pub events_executed: u64,
    /// Scheduler polling wakes among `events_executed` (always 1 in
    /// event-driven mode: the bootstrap dispatch at `t0`).
    pub wake_events: u64,
    /// Write amplification across all drives (flash pages programmed ÷
    /// host pages written; 1.0 when nothing was written).
    pub waf: f64,
    /// GC victim passes across all drives (foreground + background).
    pub gc_runs: u64,
    /// Worst per-drive max−min block erase-count spread (wear quality).
    pub wear_spread: u32,
    /// Host acks among `events_executed` (self-profiling diagnostic;
    /// like the event counts, excluded from `check_bit_identical`).
    pub host_ack_events: u64,
    /// CSD acks among `events_executed` (batched acks count each
    /// member). Excluded from `check_bit_identical`.
    pub csd_ack_events: u64,
}

impl RunReport {
    /// Fraction of input data processed in storage (Table I's
    /// "data processed in CSDs").
    pub fn csd_data_fraction(&self) -> f64 {
        if self.total_items == 0 {
            return 0.0;
        }
        self.csd_items as f64 / self.total_items as f64
    }

    /// Field-by-field bit-identity of everything a run *means*: every
    /// field except the event-count diagnostics (`events_executed`,
    /// `wake_events`), which wake coalescing changes on purpose, and the
    /// `dispatch` label, which names the mode rather than the outcome.
    /// Floats are compared on their bit patterns, not with a tolerance.
    /// Returns the first differing field. Used by the wake-coalescing
    /// property test here and by the fleet layer's 1-server-fleet ≡
    /// direct-run property ([`crate::cluster::fleet`]).
    pub fn check_bit_identical(&self, other: &RunReport) -> Result<(), String> {
        fn f64_eq(name: &str, x: f64, y: f64) -> Result<(), String> {
            if x.to_bits() == y.to_bits() {
                Ok(())
            } else {
                Err(format!("{name}: {x:?} != {y:?} (bitwise)"))
            }
        }
        fn eq<T: PartialEq + std::fmt::Debug>(name: &str, x: T, y: T) -> Result<(), String> {
            if x == y {
                Ok(())
            } else {
                Err(format!("{name}: {x:?} != {y:?}"))
            }
        }
        eq("app", self.app, other.app)?;
        eq("total_items", self.total_items, other.total_items)?;
        f64_eq("makespan_secs", self.makespan_secs, other.makespan_secs)?;
        f64_eq("items_per_sec", self.items_per_sec, other.items_per_sec)?;
        f64_eq("words_per_sec", self.words_per_sec, other.words_per_sec)?;
        eq("host_items", self.host_items, other.host_items)?;
        eq("csd_items", self.csd_items, other.csd_items)?;
        eq("pcie_bytes", self.pcie_bytes, other.pcie_bytes)?;
        eq("isp_bytes", self.isp_bytes, other.isp_bytes)?;
        eq("tunnel_messages", self.tunnel_messages, other.tunnel_messages)?;
        f64_eq("energy_j", self.energy_j, other.energy_j)?;
        f64_eq("avg_power_w", self.avg_power_w, other.avg_power_w)?;
        f64_eq("energy_per_item_j", self.energy_per_item_j, other.energy_per_item_j)?;
        f64_eq("host_busy_secs", self.host_busy_secs, other.host_busy_secs)?;
        f64_eq("isp_busy_secs", self.isp_busy_secs, other.isp_busy_secs)?;
        f64_eq("mean_batch_latency", self.mean_batch_latency, other.mean_batch_latency)?;
        eq("host_batches", self.host_batches, other.host_batches)?;
        eq("csd_batches", self.csd_batches, other.csd_batches)?;
        f64_eq("waf", self.waf, other.waf)?;
        eq("gc_runs", self.gc_runs, other.gc_runs)?;
        eq("wear_spread", self.wear_spread, other.wear_spread)?;
        Ok(())
    }
}

#[derive(Clone, Debug)]
pub(crate) enum Ev {
    /// Scheduler polling wake (always on the wake grid; in event-driven
    /// mode only the single bootstrap dispatch at `t0`).
    Wake,
    /// Host finished its batch (local ack).
    HostDone { items: u64, dispatched: f64 },
    /// CSD ack delivered over the tunnel.
    CsdAck { drive: usize, items: u64, dispatched: f64 },
    /// Several CSD acks from one wake whose delivery times are
    /// bit-identical, batched into a single calendar entry (coalesced
    /// polling mode only). Entries are `(drive, items)` in dispatch
    /// order, which is exactly the order the separate events would pop
    /// in: equal time, and all of this wake's acks are contiguous in seq
    /// order.
    CsdAckBatch { acks: Vec<(usize, u64)>, dispatched: f64 },
}

/// Pending same-timestamp ack groups accumulated during one wake's CSD
/// dispatch pass (coalesced mode). Groups keep first-occurrence order;
/// lookup is a linear scan over at most `isp_drives` entries.
struct AckGroups {
    groups: Vec<(f64, Vec<(usize, u64)>)>,
}

impl AckGroups {
    fn new() -> AckGroups {
        AckGroups { groups: Vec::new() }
    }

    fn push(&mut self, ack_time: f64, drive: usize, items: u64) {
        for (t, g) in &mut self.groups {
            if *t == ack_time {
                g.push((drive, items));
                return;
            }
        }
        self.groups.push((ack_time, vec![(drive, items)]));
    }

    /// Schedule every group: single acks stay plain `CsdAck` events,
    /// larger groups become one `CsdAckBatch`. Scheduling in
    /// first-occurrence order keeps seq order consistent with the
    /// uncoalesced run for any same-timestamp tie-breaks.
    fn schedule(self, q: &mut EventQueue<Ev>, dispatched: f64) {
        for (t, mut g) in self.groups {
            if g.len() == 1 {
                // solana-lint: allow(no-unwrap, reason = "guarded by the g.len() == 1 check on the previous line")
                let (drive, items) = g.pop().expect("non-empty group");
                q.schedule_at(t, Ev::CsdAck { drive, items, dispatched });
            } else {
                q.schedule_at(t, Ev::CsdAckBatch { acks: g, dispatched });
            }
        }
    }
}

/// Simulated dataset shard name on each drive (shared with the serving
/// frontend, whose resident corpus must be the file the dispatch paths
/// read).
pub(crate) const SHARD: &str = "shard.dat";

/// Per-dispatch-pass timing observations captured for the request
/// tracer. `None` (the default, and always in batch mode / traced-off
/// serving) keeps the dispatch bodies on exactly the pre-trace code
/// path; when armed, the bodies additionally record read-only device
/// queries — they never feed a value back into the simulation.
#[derive(Clone, Debug, Default)]
pub(crate) struct DispatchTrace {
    pub(crate) host: Option<HostBatchTiming>,
    /// `(drive, timing)` in dispatch order.
    pub(crate) csd: Vec<(usize, CsdBatchTiming)>,
}

/// Timing decomposition of one host batch dispatch.
#[derive(Clone, Copy, Debug)]
pub(crate) struct HostBatchTiming {
    /// Seconds of GC overhang (worst drive) the batch's reads queue
    /// behind at dispatch time.
    pub(crate) gc_overhang: f64,
    /// ECC-engine busy seconds consumed by this batch's flash reads.
    pub(crate) ecc_secs: f64,
    /// All shard reads landed in host memory.
    pub(crate) io_done: f64,
    /// Host compute finished (the ack time).
    pub(crate) done: f64,
}

/// Timing decomposition of one CSD batch dispatch.
#[derive(Clone, Copy, Debug)]
pub(crate) struct CsdBatchTiming {
    /// Dispatch message delivered to the ISP over the tunnel.
    pub(crate) delivered: f64,
    /// Seconds of GC overhang on this drive at delivery.
    pub(crate) gc_overhang: f64,
    /// ECC-engine busy seconds consumed by this batch's flash reads.
    pub(crate) ecc_secs: f64,
    /// Flash reads into drive DRAM finished.
    pub(crate) read_done: f64,
    /// ISP compute finished.
    pub(crate) done: f64,
    /// Result/ack delivered back to the host.
    pub(crate) ack: f64,
}

/// Mutable protocol state plus the dispatch routines shared by both
/// dispatch modes. The host- and CSD-dispatch bodies live here so the
/// `Wake` arm (polling) and the `HostDone`/`CsdAck`/`CsdAckBatch` arms
/// (event-driven) drive the *same* code — the mode only decides when it
/// runs. Polling-mode results stay bit-identical to the pre-refactor
/// runner because the bodies perform the same float operations in the
/// same order.
///
/// Crate-internal so the serving frontend ([`crate::traffic`]) can drive
/// the *same* dispatch paths over an arrival-fed queue instead of a
/// pre-loaded corpus: arrivals refill `shard_remaining` and the engine
/// calls [`SchedState::dispatch_host`] / [`SchedState::dispatch_csds`],
/// so service-time modeling (flash reads, tunnel messages, batch
/// overheads) is reused, never duplicated.
pub(crate) struct SchedState<'a> {
    pub(crate) model: &'a AppModel,
    pub(crate) cfg: &'a SchedConfig,
    pub(crate) server: StorageServer,
    pub(crate) shard_remaining: Vec<u64>,
    pub(crate) shard_offset: Vec<u64>,
    pub(crate) host_idle: bool,
    /// Idle-drive index: the ISP drives currently waiting for a batch,
    /// in ascending drive order (BTreeSet iteration), so CSD dispatch
    /// walks only idle drives yet visits them in exactly the order the
    /// plain 0..isp_drives scan would. Drives whose shard has drained
    /// are retired from the index (batch mode: shards never refill; the
    /// serving frontend re-inserts a drive when a request lands on it).
    pub(crate) idle_isp: std::collections::BTreeSet<usize>,
    cand_buf: Vec<usize>,
    pub(crate) csd_busy: usize,
    /// Incremental bookkeeping: running count instead of an O(drives)
    /// `shard_remaining.iter().sum()` on every dispatch pass.
    pub(crate) total_remaining: u64,
    pub(crate) host_items: u64,
    pub(crate) csd_items: u64,
    pub(crate) host_busy_secs: f64,
    pub(crate) isp_busy_secs: f64,
    pub(crate) host_batches: u64,
    pub(crate) csd_batches: u64,
    pub(crate) last_completion: f64,
    latency_sum: f64,
    latency_n: u64,
    host_batch_target: u64,
    host_lat: HistogramId,
    csd_lat: HistogramId,
    /// Armed by the serving engine (only while its tracer is on) before
    /// each dispatch pass; the dispatch bodies fill it with read-only
    /// timing observations. `None` everywhere else.
    pub(crate) trace: Option<Box<DispatchTrace>>,
}

impl<'a> SchedState<'a> {
    /// Build the protocol state over an already-ingested set of shards.
    /// `t0` is the clock origin (ingest completion). Histogram handles
    /// resolve against `metrics` once, here, so the ack hot path never
    /// allocates a key string.
    pub(crate) fn new(
        model: &'a AppModel,
        cfg: &'a SchedConfig,
        server: StorageServer,
        shard_remaining: Vec<u64>,
        t0: f64,
        metrics: &mut Metrics,
    ) -> SchedState<'a> {
        let total_remaining = shard_remaining.iter().sum();
        SchedState {
            model,
            cfg,
            server,
            shard_remaining,
            shard_offset: vec![0; cfg.drives],
            host_idle: true,
            idle_isp: (0..cfg.isp_drives).collect(),
            cand_buf: Vec::with_capacity(cfg.isp_drives),
            csd_busy: 0,
            total_remaining,
            host_items: 0,
            csd_items: 0,
            host_busy_secs: 0.0,
            isp_busy_secs: 0.0,
            host_batches: 0,
            csd_batches: 0,
            last_completion: t0,
            latency_sum: 0.0,
            latency_n: 0,
            host_batch_target: cfg.host_batch(),
            host_lat: metrics.histogram_id("sched.host_batch_latency"),
            csd_lat: metrics.histogram_id("sched.csd_batch_latency"),
            trace: None,
        }
    }

    /// Absorb a host ack: the host is idle again.
    pub(crate) fn host_done(&mut self, now: f64, items: u64, dispatched: f64, metrics: &mut Metrics) {
        self.host_idle = true;
        self.host_items += items;
        self.last_completion = now;
        self.latency_sum += now - dispatched;
        self.latency_n += 1;
        metrics.observe_id(self.host_lat, now - dispatched);
    }

    /// Absorb one CSD ack: the drive is idle again.
    pub(crate) fn csd_ack(&mut self, now: f64, drive: usize, items: u64, dispatched: f64, metrics: &mut Metrics) {
        self.csd_busy -= 1;
        self.idle_isp.insert(drive);
        self.csd_items += items;
        self.last_completion = now;
        self.latency_sum += now - dispatched;
        self.latency_n += 1;
        metrics.observe_id(self.csd_lat, now - dispatched);
    }

    /// Hand the host its next batch if it is idle and work remains.
    /// Called from the `Wake` arm (polling) and from `HostDone`
    /// (event-driven).
    pub(crate) fn dispatch_host(&mut self, now: f64, q: &mut EventQueue<Ev>) -> anyhow::Result<()> {
        let remaining_at_wake = self.total_remaining;
        if !(self.cfg.use_host && self.host_idle && remaining_at_wake > 0) {
            return Ok(());
        }
        // Near the end of the run the host's batch shrinks to its *fair
        // share* of what's left, so host and CSDs drain together instead
        // of leaving a long CSD tail.
        let fair = if self.cfg.use_isp() && self.cfg.fair_tail {
            let host_rate = HOST_THREADS / self.model.host_item_secs;
            let csd_rate = self.cfg.isp_drives as f64 * ISP_CORES / self.model.csd_item_secs;
            ((remaining_at_wake as f64 * host_rate / (host_rate + csd_rate)).ceil() as u64).max(1)
        } else {
            remaining_at_wake
        };
        let take = self.host_batch_target.min(remaining_at_wake).min(fair);
        // Read-only tracer snapshots (no-ops unless the serving engine
        // armed `self.trace`; never fed back into the simulation).
        let tracing = self.trace.is_some();
        let mut ecc_before = 0.0;
        let mut gc_overhang = 0.0;
        if tracing {
            for d in 0..self.cfg.drives {
                ecc_before += self.server.ecc_busy_secs(d);
                gc_overhang = gc_overhang.max(self.server.gc_busy_until(d) - now);
            }
        }
        // Proportional take across shards: every drive's shard drains at
        // the same fractional rate, keeping each CSD's local work alive
        // (an ISP can only process items on its own flash). On ISP
        // drives the host additionally leaves one CSD batch in reserve;
        // the reservation lapses when the host would otherwise idle
        // (pass 1).
        let mut left = take;
        let mut io_done = now;
        for pass in 0..2 {
            for d in 0..self.cfg.drives {
                if left == 0 {
                    break;
                }
                let avail = self.shard_remaining[d];
                let cap = if pass == 0 && d < self.cfg.isp_drives {
                    avail.saturating_sub(self.cfg.csd_batch)
                } else {
                    avail
                };
                let share = if pass == 0 {
                    // `take` and `avail` are both item counts that reach
                    // 2^32+ at paper-scale corpora; the product needs a
                    // u128 intermediate (ISSUE-2 satellite).
                    crate::util::mul_div_ceil(take, avail, remaining_at_wake.max(1))
                } else {
                    left
                };
                let n = left.min(cap).min(share);
                if n == 0 {
                    continue;
                }
                let bytes = n * self.model.bytes_per_item;
                let r = self.server.host_read(now, d, SHARD, self.shard_offset[d], bytes)?;
                self.shard_offset[d] += bytes;
                self.shard_remaining[d] -= n;
                self.total_remaining -= n;
                left -= n;
                io_done = io_done.max(r.done);
            }
            // Second pass (ignores reservations) only when the host
            // would otherwise sit completely idle.
            if left < take || !self.cfg.use_isp() {
                break;
            }
        }
        let taken = take - left;
        if taken > 0 {
            let compute = self.model.host_batch_overhead
                + taken as f64 * self.model.host_item_secs / HOST_THREADS;
            let done = io_done + compute;
            self.host_busy_secs += done - now;
            self.host_idle = false;
            self.host_batches += 1;
            if tracing {
                let mut ecc_after = 0.0;
                for d in 0..self.cfg.drives {
                    ecc_after += self.server.ecc_busy_secs(d);
                }
                if let Some(tr) = self.trace.as_mut() {
                    tr.host = Some(HostBatchTiming {
                        gc_overhang: gc_overhang.max(0.0),
                        ecc_secs: (ecc_after - ecc_before).max(0.0),
                        io_done,
                        done,
                    });
                }
            }
            q.schedule_at(done, Ev::HostDone { items: taken, dispatched: now });
        }
        Ok(())
    }

    /// Hand every idle ISP drive with local work its next batch. Called
    /// from the `Wake` arm (polling) and from the ack arms
    /// (event-driven, where the idle set is typically just the drive
    /// that acked). `coalesce` batches same-timestamp acks into one
    /// calendar entry (coalesced polling mode only).
    pub(crate) fn dispatch_csds(&mut self, now: f64, q: &mut EventQueue<Ev>, coalesce: bool) -> anyhow::Result<()> {
        if !self.cfg.use_isp() || self.idle_isp.is_empty() {
            return Ok(());
        }
        self.cand_buf.clear();
        self.cand_buf.extend(self.idle_isp.iter().copied());
        let tracing = self.trace.is_some();
        let mut groups = AckGroups::new();
        for i in 0..self.cand_buf.len() {
            let d = self.cand_buf[i];
            if self.shard_remaining[d] == 0 {
                // An empty shard never refills: retire the drive from
                // the idle index for good.
                self.idle_isp.remove(&d);
                continue;
            }
            let n = self.cfg.csd_batch.min(self.shard_remaining[d]);
            self.shard_remaining[d] -= n;
            self.total_remaining -= n;
            // dispatch message: header + the item indexes only
            let delivered = self.server.send_to_isp(now, d, 64 + 8 * n);
            let ecc_before = if tracing { self.server.ecc_busy_secs(d) } else { 0.0 };
            let bytes = n * self.model.bytes_per_item;
            let r = self.server.isp_read(delivered, d, SHARD, self.shard_offset[d], bytes)?;
            self.shard_offset[d] += bytes;
            let compute = self.model.csd_batch_overhead
                + n as f64 * self.model.csd_item_secs / ISP_CORES;
            let done = r.done + compute;
            // result + ack back over the tunnel
            let ack = self
                .server
                .send_to_host(done, d, 64 + n * self.model.output_bytes_per_item);
            if tracing {
                let gc_overhang = (self.server.gc_busy_until(d) - delivered).max(0.0);
                let ecc_secs = (self.server.ecc_busy_secs(d) - ecc_before).max(0.0);
                if let Some(tr) = self.trace.as_mut() {
                    tr.csd.push((
                        d,
                        CsdBatchTiming {
                            delivered,
                            gc_overhang,
                            ecc_secs,
                            read_done: r.done,
                            done,
                            ack,
                        },
                    ));
                }
            }
            self.isp_busy_secs += done - delivered;
            self.idle_isp.remove(&d);
            self.csd_busy += 1;
            self.csd_batches += 1;
            if coalesce {
                groups.push(ack, d, n);
            } else {
                q.schedule_at(ack, Ev::CsdAck { drive: d, items: n, dispatched: now });
            }
        }
        groups.schedule(q, now);
        Ok(())
    }
}

/// Run one benchmark under the scheduler; returns the report.
///
/// `server` should be freshly built; this function ingests the dataset
/// shards, runs the full protocol in virtual time, and reads the
/// counters back out of the device models.
pub fn run(
    model: &AppModel,
    cfg: &SchedConfig,
    power: &PowerModel,
    metrics: &mut Metrics,
) -> anyhow::Result<RunReport> {
    anyhow::ensure!(cfg.drives > 0, "need at least one drive for data");
    anyhow::ensure!(cfg.isp_drives <= cfg.drives, "isp_drives exceeds drives");
    anyhow::ensure!(cfg.use_host || cfg.use_isp(), "no compute nodes enabled");
    anyhow::ensure!(
        cfg.wakeup_secs > 0.0 && cfg.wakeup_secs.is_finite(),
        "wakeup_secs must be positive and finite, got {}",
        cfg.wakeup_secs
    );
    let mut server = StorageServer::new(cfg.drives, cfg.csd.clone());

    // ---- ingest: stripe the dataset across drives --------------------
    let items_per_drive = crate::util::div_ceil(model.items, cfg.drives as u64);
    let mut shard_remaining: Vec<u64> = Vec::with_capacity(cfg.drives);
    let mut assigned = model.items;
    let mut ingest_done = 0.0f64;
    for d in 0..cfg.drives {
        let n = assigned.min(items_per_drive);
        assigned -= n;
        shard_remaining.push(n);
        let bytes = (n * model.bytes_per_item).max(1);
        ingest_done = ingest_done.max(server.ingest(0.0, d, SHARD, bytes)?);
    }
    debug_assert_eq!(assigned, 0);
    // The benchmark clock starts after the dataset is resident (the paper
    // measures steady-state processing, not ingest).
    let t0 = ingest_done;

    // ---- event loop ---------------------------------------------------
    let mut q: EventQueue<Ev> = EventQueue::new();
    q.schedule_at(t0, Ev::Wake);

    let event_driven = cfg.dispatch == DispatchMode::EventDriven;
    let mut st = SchedState::new(model, cfg, server, shard_remaining, t0, metrics);
    let mut wake_events = 0u64;
    let mut host_ack_events = 0u64;
    let mut csd_ack_events = 0u64;

    while let Some((now, ev)) = q.pop() {
        match ev {
            Ev::HostDone { items, dispatched } => {
                host_ack_events += 1;
                st.host_done(now, items, dispatched, metrics);
                if event_driven {
                    // Re-arm the host the moment its ack pops (off-grid).
                    st.dispatch_host(now, &mut q)?;
                }
            }
            Ev::CsdAck { drive, items, dispatched } => {
                csd_ack_events += 1;
                st.csd_ack(now, drive, items, dispatched, metrics);
                if event_driven {
                    st.dispatch_csds(now, &mut q, false)?;
                }
            }
            Ev::CsdAckBatch { acks, dispatched } => {
                // Batched acks exist only in coalesced polling mode:
                // every event-driven dispatch_csds call passes
                // coalesce = false, so no re-dispatch is needed here.
                debug_assert!(!event_driven, "CsdAckBatch cannot occur in event-driven mode");
                csd_ack_events += acks.len() as u64;
                for (drive, items) in acks {
                    st.csd_ack(now, drive, items, dispatched, metrics);
                }
            }
            Ev::Wake => {
                wake_events += 1;
                st.dispatch_host(now, &mut q)?;
                st.dispatch_csds(now, &mut q, !event_driven && cfg.coalesce_wakes)?;
                // ---- keep polling while anything is outstanding ------
                // (polling mode only: event-driven re-arms from the ack
                // arms, so the bootstrap wake is the only grid point.)
                if !event_driven {
                    let work_left = st.total_remaining > 0;
                    let busy = !st.host_idle || st.csd_busy > 0;
                    if work_left || busy {
                        let mut next = now + cfg.wakeup_secs;
                        if cfg.coalesce_wakes {
                            // A completed wake leaves nothing
                            // dispatchable (see the module docs), so
                            // every grid point strictly before the next
                            // pending ack is a no-op wake: walk the grid
                            // past them. The walk repeats the naive
                            // chain's additions so the chosen wake
                            // timestamp is bit-identical to the wake the
                            // naive run would execute.
                            if let Some(t_next_ev) = q.peek_time() {
                                while next < t_next_ev {
                                    next += cfg.wakeup_secs;
                                }
                            }
                        }
                        q.schedule_at(next, Ev::Wake);
                    }
                }
            }
        }
    }

    // ---- conservation check -------------------------------------------
    let processed = st.host_items + st.csd_items;
    anyhow::ensure!(
        processed == model.items,
        "scheduler lost items: {processed} != {}",
        model.items
    );

    let makespan = (st.last_completion - t0).max(1e-9);
    let items_per_sec = model.items as f64 / makespan;
    let energy = power.energy(
        makespan,
        cfg.drives,
        st.host_busy_secs.min(makespan),
        st.isp_busy_secs,
    );

    // PCIe bytes after ingest: subtract what ingest itself pushed.
    let ingest_pcie: u64 = (0..cfg.drives)
        .map(|d| {
            let n = items_per_drive.min(model.items.saturating_sub(items_per_drive * d as u64));
            (n * model.bytes_per_item).max(1)
        })
        .sum();
    let pcie_total = st.server.total_pcie_bytes();
    let pcie_bytes = pcie_total.saturating_sub(ingest_pcie);
    let isp_bytes: u64 = st.server.bays.iter().map(|b| b.csd.fcu.io.isp_read_bytes).sum();
    let (ftl, wear_spread) = st.server.ftl_rollup();

    metrics.inc("sched.items", model.items as f64);
    metrics.inc("sched.host_items", st.host_items as f64);
    metrics.inc("sched.csd_items", st.csd_items as f64);
    metrics.inc("io.pcie_bytes", pcie_bytes as f64);
    metrics.inc("io.isp_bytes", isp_bytes as f64);
    metrics.inc("energy.joules", energy.energy_j);

    Ok(RunReport {
        app: model.app.name(),
        dispatch: cfg.dispatch.name(),
        total_items: model.items,
        makespan_secs: makespan,
        items_per_sec,
        words_per_sec: items_per_sec * model.words_per_item,
        host_items: st.host_items,
        csd_items: st.csd_items,
        pcie_bytes,
        isp_bytes,
        tunnel_messages: st.server.total_tunnel_messages(),
        energy_j: energy.energy_j,
        avg_power_w: energy.avg_power_w,
        energy_per_item_j: energy.energy_j / model.items as f64,
        host_busy_secs: st.host_busy_secs,
        isp_busy_secs: st.isp_busy_secs,
        mean_batch_latency: if st.latency_n > 0 {
            st.latency_sum / st.latency_n as f64
        } else {
            0.0
        },
        host_batches: st.host_batches,
        csd_batches: st.csd_batches,
        events_executed: q.events_executed(),
        wake_events,
        waf: ftl.waf(),
        gc_runs: ftl.gc_runs,
        wear_spread,
        host_ack_events,
        csd_ack_events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, forall};
    use crate::workloads::App;

    fn quick(model: AppModel, cfg: SchedConfig) -> RunReport {
        let mut m = Metrics::new();
        run(&model, &cfg, &PowerModel::default(), &mut m).unwrap()
    }

    /// Field-by-field bit-identity of everything a run *means* — see
    /// [`RunReport::check_bit_identical`] (shared with the fleet layer's
    /// 1-server property test).
    fn check_reports_bit_identical(a: &RunReport, b: &RunReport) -> Result<(), String> {
        a.check_bit_identical(b)
    }

    #[test]
    fn property_coalescing_is_bit_identical_across_apps_and_configs() {
        forall("wake coalescing equivalence", 10, |g| {
            let drives = g.usize(1..=36);
            let isp_drives = g.usize(0..=drives);
            let items = g.u64(500..=20_000);
            let batch = g.u64(1..=2_000);
            let ratio = g.f64(1.0, 30.0);
            let wakeup = [0.05, 0.1, 0.2, 0.5][g.usize(0..=3)];
            let fair_tail = g.bool();
            let app = *g.rng().choose(&App::all());
            let model = AppModel::for_app(app, items);
            let mk = |coalesce: bool| SchedConfig {
                csd_batch: batch,
                batch_ratio: ratio,
                wakeup_secs: wakeup,
                drives,
                isp_drives,
                use_host: true,
                fair_tail,
                coalesce_wakes: coalesce,
                dispatch: DispatchMode::Polling,
                seed: 42,
                csd: CsdConfig::default(),
            };
            let run_one = |coalesce: bool| -> Result<RunReport, String> {
                let mut m = Metrics::new();
                run(&model, &mk(coalesce), &PowerModel::default(), &mut m)
                    .map_err(|e| e.to_string())
            };
            let naive = run_one(false)?;
            let coal = run_one(true)?;
            check_reports_bit_identical(&naive, &coal).map_err(|e| {
                format!("{app:?} drives={drives} isp={isp_drives} items={items} batch={batch} ratio={ratio:.2} wakeup={wakeup} fair_tail={fair_tail}: {e}")
            })?;
            check(
                coal.events_executed <= naive.events_executed,
                format!(
                    "coalescing executed more events: {} > {}",
                    coal.events_executed, naive.events_executed
                ),
            )
        });
    }

    #[test]
    fn property_event_driven_conserves_and_is_never_slower() {
        // ISSUE-2 satellite: event-driven dispatch hands out every batch
        // at or before the grid point polling would have used, so across
        // randomized configs × all three apps it conserves items and
        // never yields a longer makespan (up to float noise).
        //
        // Scope note: the sweep pins `fair_tail: true` (the default, and
        // what A4 and every operating-point gate use). Under the paper's
        // plain tail (`fair_tail: false`) the pass-1 reservation lapse
        // lets whichever host dispatch happens to land on an
        // all-reserved tail swallow it wholesale, so dispatch *timing*
        // can reassign a whole tail batch between a fast host and a slow
        // CSD in either direction — a Graham-style anomaly of the
        // paper's scheduler itself, not of the dispatch mode.
        forall("event-driven dispatch dominance", 10, |g| {
            let drives = g.usize(1..=36);
            let isp_drives = g.usize(0..=drives);
            let items = g.u64(500..=20_000);
            let batch = g.u64(1..=2_000);
            let ratio = g.f64(1.0, 30.0);
            let wakeup = [0.05, 0.1, 0.2, 0.5][g.usize(0..=3)];
            let app = *g.rng().choose(&App::all());
            let model = AppModel::for_app(app, items);
            let mk = |dispatch: DispatchMode| SchedConfig {
                csd_batch: batch,
                batch_ratio: ratio,
                wakeup_secs: wakeup,
                drives,
                isp_drives,
                fair_tail: true,
                dispatch,
                ..SchedConfig::default()
            };
            let run_one = |dispatch: DispatchMode| -> Result<RunReport, String> {
                let mut m = Metrics::new();
                run(&model, &mk(dispatch), &PowerModel::default(), &mut m)
                    .map_err(|e| e.to_string())
            };
            let poll = run_one(DispatchMode::Polling)?;
            let event = run_one(DispatchMode::EventDriven)?;
            let ctx = format!(
                "{app:?} drives={drives} isp={isp_drives} items={items} batch={batch} ratio={ratio:.2} wakeup={wakeup}"
            );
            check(
                event.host_items + event.csd_items == event.total_items,
                format!(
                    "{ctx}: event-driven lost items: {} + {} != {}",
                    event.host_items, event.csd_items, event.total_items
                ),
            )?;
            check(
                event.makespan_secs <= poll.makespan_secs + 1e-9,
                format!(
                    "{ctx}: event-driven slower: {} > {}",
                    event.makespan_secs, poll.makespan_secs
                ),
            )?;
            check(
                event.wake_events == 1,
                format!("{ctx}: expected 1 bootstrap wake, saw {}", event.wake_events),
            )
        });
    }

    #[test]
    fn event_driven_beats_polling_on_fig5a_speech() {
        // The ISSUE-2 regression gate: the paper's Fig 5(a) operating
        // point (speech, csd_batch=6, 36 drives, 13,100 clips). At this
        // point every node pays a mean half-period (~0.1 s) idle gap per
        // batch under polling; event-driven removes it, so the makespan
        // must strictly improve while conserving items.
        let mk = |dispatch: DispatchMode| SchedConfig {
            csd_batch: 6,
            batch_ratio: 20.0,
            dispatch,
            ..SchedConfig::default()
        };
        let poll = quick(AppModel::speech(13_100), mk(DispatchMode::Polling));
        let event = quick(AppModel::speech(13_100), mk(DispatchMode::EventDriven));
        assert_eq!(event.host_items + event.csd_items, 13_100);
        assert_eq!(event.wake_events, 1, "event-driven runs off a single bootstrap wake");
        assert!(
            event.makespan_secs < poll.makespan_secs,
            "event-driven should beat polling: {} !< {}",
            event.makespan_secs,
            poll.makespan_secs
        );
        let speedup = poll.makespan_secs / event.makespan_secs;
        assert!(
            speedup < 2.0,
            "sanity: off-grid dispatch only removes sub-period idle gaps, got {speedup:.3}x"
        );
    }

    #[test]
    fn proportional_host_share_survives_paper_scale_corpora() {
        // ISSUE-2 satellite regression: the pass-0 proportional share
        // used to compute `take * avail` in u64, which overflows once
        // the corpus passes ~2^32 items with a large host batch. Here
        // 12 G items on 3 drives with a 10 G-item host batch puts
        // `take * avail` ≈ 4.0e19 > u64::MAX ≈ 1.8e19 on the very first
        // dispatch; the share now widens through u128.
        let items: u64 = 12_000_000_000;
        let model = AppModel {
            app: App::Sentiment,
            items,
            bytes_per_item: 1, // keep simulated flash traffic tractable
            output_bytes_per_item: 1,
            host_item_secs: 16.0 / 2.0e8,
            csd_item_secs: 4.0 / 1.0e7,
            host_batch_overhead: 0.05,
            csd_batch_overhead: 0.20,
            words_per_item: 1.0,
        };
        let cfg = SchedConfig {
            csd_batch: 500_000_000,
            batch_ratio: 20.0, // host batch = 1e10 items
            drives: 3,
            isp_drives: 3,
            fair_tail: false, // host takes its full batch: max overflow pressure
            ..SchedConfig::default()
        };
        let r = quick(model, cfg);
        assert_eq!(r.host_items + r.csd_items, items);
        assert!(r.host_items > 0 && r.csd_items > 0);
    }

    #[test]
    fn coalescing_cuts_events_on_fig5a_speech() {
        // The ISSUE-1 regression gate: the paper's Fig 5(a) operating
        // point (speech, csd_batch=6, 36 drives, 13,100 clips).
        let mk = |coalesce: bool| SchedConfig {
            csd_batch: 6,
            batch_ratio: 20.0,
            coalesce_wakes: coalesce,
            ..SchedConfig::default()
        };
        let naive = quick(AppModel::speech(13_100), mk(false));
        let coal = quick(AppModel::speech(13_100), mk(true));
        check_reports_bit_identical(&naive, &coal).unwrap();
        assert!(
            naive.events_executed >= 5 * coal.events_executed,
            "events_executed should drop >= 5x: naive {} vs coalesced {}",
            naive.events_executed,
            coal.events_executed
        );
        assert!(
            naive.wake_events >= 5 * coal.wake_events,
            "wake_events should drop >= 5x: naive {} vs coalesced {}",
            naive.wake_events,
            coal.wake_events
        );
    }

    #[test]
    fn conservation_host_only() {
        let r = quick(
            AppModel::sentiment(50_000),
            SchedConfig { isp_drives: 0, drives: 4, csd_batch: 5_000, ..Default::default() },
        );
        assert_eq!(r.host_items, 50_000);
        assert_eq!(r.csd_items, 0);
        assert_eq!(r.csd_batches, 0);
    }

    #[test]
    fn conservation_with_isp() {
        let r = quick(
            AppModel::sentiment(100_000),
            SchedConfig { drives: 8, isp_drives: 8, csd_batch: 2_000, batch_ratio: 26.0, ..Default::default() },
        );
        assert_eq!(r.host_items + r.csd_items, 100_000);
        assert!(r.csd_items > 0, "ISPs processed something");
        assert!(r.host_items > r.csd_items, "host is much faster");
    }

    #[test]
    fn event_driven_conserves_in_host_only_and_csd_only_runs() {
        // Host-only: the host re-arms itself off its own acks.
        let host_only = quick(
            AppModel::sentiment(50_000),
            SchedConfig {
                isp_drives: 0,
                drives: 4,
                csd_batch: 5_000,
                dispatch: DispatchMode::EventDriven,
                ..Default::default()
            },
        );
        assert_eq!(host_only.host_items, 50_000);
        assert_eq!(host_only.csd_items, 0);
        // CSD-only: each drive re-arms off its own ack until its shard
        // drains.
        let csd_only = quick(
            AppModel::sentiment(20_000),
            SchedConfig {
                drives: 4,
                isp_drives: 4,
                csd_batch: 500,
                use_host: false,
                dispatch: DispatchMode::EventDriven,
                ..Default::default()
            },
        );
        assert_eq!(csd_only.csd_items, 20_000);
        assert_eq!(csd_only.host_items, 0);
    }

    #[test]
    fn isp_speedup_over_baseline() {
        // Full LJ-sized corpus, paper's Fig 5(a) best configuration.
        let base = quick(AppModel::speech(13_100), SchedConfig::baseline(36));
        let isp = quick(
            AppModel::speech(13_100),
            SchedConfig { csd_batch: 6, batch_ratio: 20.0, drives: 36, ..Default::default() },
        );
        let speedup = isp.words_per_sec / base.words_per_sec;
        assert!(
            (2.6..3.4).contains(&speedup),
            "paper: ~3.1x (296 vs 96 w/s); got {speedup:.2} ({:.1} vs {:.1} w/s)",
            isp.words_per_sec,
            base.words_per_sec
        );
        // absolute rates in the paper's ballpark
        assert!((250.0..320.0).contains(&isp.words_per_sec));
        assert!((90.0..110.0).contains(&base.words_per_sec));
    }

    #[test]
    fn isp_path_reduces_pcie_traffic() {
        let base = quick(AppModel::speech(1_310), SchedConfig::baseline(12));
        let isp = quick(
            AppModel::speech(1_310),
            SchedConfig { drives: 12, isp_drives: 12, csd_batch: 6, ..Default::default() },
        );
        assert!(isp.pcie_bytes < base.pcie_bytes);
        assert!(isp.isp_bytes > 0);
        // baseline moves every byte over PCIe
        assert_eq!(base.pcie_bytes, 1_310 * 290_000);
    }

    #[test]
    fn energy_per_item_improves_with_isp() {
        let base = quick(AppModel::sentiment(200_000), SchedConfig::baseline(36));
        let isp = quick(
            AppModel::sentiment(200_000),
            SchedConfig { drives: 36, isp_drives: 36, csd_batch: 40_000, batch_ratio: 26.0, ..Default::default() },
        );
        assert!(
            isp.energy_per_item_j < base.energy_per_item_j * 0.7,
            "paper: ≥54% saving; got {} vs {}",
            isp.energy_per_item_j,
            base.energy_per_item_j
        );
    }

    #[test]
    fn zero_drives_rejected() {
        let mut m = Metrics::new();
        let cfg = SchedConfig { drives: 0, ..Default::default() };
        assert!(run(&AppModel::sentiment(10), &cfg, &PowerModel::default(), &mut m).is_err());
    }

    #[test]
    fn throughput_scales_with_drives() {
        let apps = [App::Sentiment];
        for app in apps {
            let items = 2_000_000;
            let mk = |drives| {
                quick(
                    AppModel::for_app(app, items),
                    SchedConfig {
                        drives,
                        isp_drives: drives,
                        csd_batch: 10_000,
                        batch_ratio: 26.0,
                        ..Default::default()
                    },
                )
            };
            let r9 = mk(9);
            let r36 = mk(36);
            assert!(
                r36.items_per_sec > r9.items_per_sec * 1.3,
                "{app:?}: 36 drives {} !> 9 drives {}",
                r36.items_per_sec,
                r9.items_per_sec
            );
        }
    }
}
