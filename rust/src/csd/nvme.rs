//! NVMe command-level front-end model: submission/completion queue
//! pairs, doorbells, round-robin arbitration, and command validation.
//!
//! §III-A1: "One of the main modules of the FE subsystem is the
//! NVMe/PCIe interface… The FE is responsible for receiving the IO
//! commands from the host, checking their integrity and correctness, and
//! interpreting them." This module models that pipeline at command
//! granularity; it also carries the **vendor-specific commands** the
//! TCP/IP tunnel is built on (§III-C3) — see
//! [`crate::interconnect::tunnel_proto`].

use std::collections::VecDeque;

use crate::sim::{Servers, SimTime};

/// NVMe opcode subset used by the stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Opcode {
    Read,
    Write,
    Flush,
    /// Vendor command carrying a tunnel frame (paper path "c").
    VendorTunnelTx,
    VendorTunnelRx,
    Identify,
}

impl Opcode {
    /// Admin commands go to the admin queue; IO commands to IO queues.
    pub fn is_admin(&self) -> bool {
        matches!(self, Opcode::Identify)
    }
}

/// One submission-queue entry (the fields the model needs).
#[derive(Clone, Debug)]
pub struct Command {
    pub opcode: Opcode,
    /// Starting byte (LBA × block size precomputed by the driver).
    pub start_byte: u64,
    pub bytes: u64,
    pub qid: u16,
    pub cid: u16,
}

/// NVMe status codes (subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    Success,
    InvalidOpcode,
    InvalidField,
    LbaOutOfRange,
    QueueFull,
}

/// A completion-queue entry.
#[derive(Clone, Debug)]
pub struct Completion {
    pub cid: u16,
    pub qid: u16,
    pub status: Status,
    /// When the completion was posted (doorbell time included).
    pub posted_at: SimTime,
}

/// One SQ/CQ pair with bounded depth.
#[derive(Debug)]
struct QueuePair {
    depth: usize,
    sq: VecDeque<(SimTime, Command)>,
    submitted: u64,
    completed: u64,
}

/// The NVMe front-end: queue pairs + command processor.
///
/// Processing cost: fixed per-command decode/validate time on one of two
/// FE microengines (fetch, parse, PRP walk), matching the class of
/// embedded FE in the Solana ASIC. Data movement itself is *not* modeled
/// here — the BE and DMA paths charge it (see [`super::fcu`]).
pub struct NvmeFrontEnd {
    pairs: Vec<QueuePair>,
    engines: Servers,
    /// Per-command decode+validate cost (s).
    pub cmd_cost: SimTime,
    /// Device capacity for LBA range validation.
    capacity: u64,
    pub rejected: u64,
}

impl NvmeFrontEnd {
    pub fn new(n_io_queues: u16, depth: usize, cmd_cost: SimTime, capacity: u64) -> Self {
        // queue 0 is the admin queue
        let pairs = (0..=n_io_queues)
            .map(|_| QueuePair { depth, sq: VecDeque::new(), submitted: 0, completed: 0 })
            .collect();
        NvmeFrontEnd {
            pairs,
            engines: Servers::new(2),
            cmd_cost,
            capacity,
            rejected: 0,
        }
    }

    pub fn queues(&self) -> usize {
        self.pairs.len()
    }

    /// Ring the doorbell: enqueue a command at `now`. Returns an error
    /// completion immediately on queue-full.
    pub fn submit(&mut self, now: SimTime, cmd: Command) -> Result<(), Completion> {
        let qid = cmd.qid as usize;
        if qid >= self.pairs.len() {
            self.rejected += 1;
            return Err(Completion {
                cid: cmd.cid,
                qid: cmd.qid,
                status: Status::InvalidField,
                posted_at: now,
            });
        }
        let q = &mut self.pairs[qid];
        if q.sq.len() >= q.depth {
            self.rejected += 1;
            return Err(Completion {
                cid: cmd.cid,
                qid: cmd.qid,
                status: Status::QueueFull,
                posted_at: now,
            });
        }
        q.submitted += 1;
        q.sq.push_back((now, cmd));
        Ok(())
    }

    fn validate(&self, cmd: &Command) -> Status {
        match cmd.opcode {
            Opcode::Read | Opcode::Write => {
                if cmd.bytes == 0 {
                    Status::InvalidField
                } else if cmd.start_byte + cmd.bytes > self.capacity {
                    Status::LbaOutOfRange
                } else {
                    Status::Success
                }
            }
            Opcode::Flush | Opcode::Identify => Status::Success,
            Opcode::VendorTunnelTx | Opcode::VendorTunnelRx => {
                // tunnel frames are bounded by the shared-DRAM ring slot
                if cmd.bytes <= 64 * 1024 {
                    Status::Success
                } else {
                    Status::InvalidField
                }
            }
        }
    }

    /// Drain all queued commands (round-robin across queue pairs, admin
    /// queue first), charging FE processing time. Returns the validated
    /// commands (with their FE-done times) and error completions.
    pub fn process(&mut self, now: SimTime) -> (Vec<(SimTime, Command)>, Vec<Completion>) {
        let mut ready = Vec::new();
        let mut errors = Vec::new();
        loop {
            let mut progressed = false;
            for qid in 0..self.pairs.len() {
                let Some((arrival, cmd)) = self.pairs[qid].sq.pop_front() else {
                    continue;
                };
                progressed = true;
                let start = now.max(arrival);
                let done = self.engines.acquire(start, self.cmd_cost);
                let status = self.validate(&cmd);
                self.pairs[qid].completed += 1;
                if status == Status::Success {
                    ready.push((done, cmd));
                } else {
                    self.rejected += 1;
                    errors.push(Completion {
                        cid: cmd.cid,
                        qid: cmd.qid,
                        status,
                        posted_at: done,
                    });
                }
            }
            if !progressed {
                break;
            }
        }
        (ready, errors)
    }

    pub fn stats(&self) -> (u64, u64, u64) {
        let submitted = self.pairs.iter().map(|p| p.submitted).sum();
        let completed = self.pairs.iter().map(|p| p.completed).sum();
        (submitted, completed, self.rejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fe() -> NvmeFrontEnd {
        NvmeFrontEnd::new(4, 8, 5e-6, 1 << 30)
    }

    fn cmd(op: Opcode, qid: u16, cid: u16, start: u64, bytes: u64) -> Command {
        Command { opcode: op, start_byte: start, bytes, qid, cid }
    }

    #[test]
    fn submit_process_roundtrip() {
        let mut f = fe();
        f.submit(0.0, cmd(Opcode::Read, 1, 1, 0, 4096)).unwrap();
        f.submit(0.0, cmd(Opcode::Write, 2, 2, 4096, 4096)).unwrap();
        let (ready, errors) = f.process(0.0);
        assert_eq!(ready.len(), 2);
        assert!(errors.is_empty());
        // FE time charged
        assert!(ready.iter().all(|(t, _)| *t >= 5e-6));
        let (s, c, r) = f.stats();
        assert_eq!((s, c, r), (2, 2, 0));
    }

    #[test]
    fn queue_full_backpressure() {
        let mut f = fe();
        for i in 0..8 {
            f.submit(0.0, cmd(Opcode::Read, 1, i, 0, 4096)).unwrap();
        }
        let err = f.submit(0.0, cmd(Opcode::Read, 1, 99, 0, 4096)).unwrap_err();
        assert_eq!(err.status, Status::QueueFull);
    }

    #[test]
    fn lba_out_of_range_rejected() {
        let mut f = fe();
        f.submit(0.0, cmd(Opcode::Read, 1, 1, (1 << 30) - 100, 4096)).unwrap();
        let (ready, errors) = f.process(0.0);
        assert!(ready.is_empty());
        assert_eq!(errors[0].status, Status::LbaOutOfRange);
    }

    #[test]
    fn zero_length_io_rejected() {
        let mut f = fe();
        f.submit(0.0, cmd(Opcode::Write, 1, 1, 0, 0)).unwrap();
        let (_, errors) = f.process(0.0);
        assert_eq!(errors[0].status, Status::InvalidField);
    }

    #[test]
    fn vendor_tunnel_commands_validated() {
        let mut f = fe();
        f.submit(0.0, cmd(Opcode::VendorTunnelTx, 1, 1, 0, 1500)).unwrap();
        f.submit(0.0, cmd(Opcode::VendorTunnelTx, 1, 2, 0, 1 << 20)).unwrap();
        let (ready, errors) = f.process(0.0);
        assert_eq!(ready.len(), 1, "MTU-sized frame passes");
        assert_eq!(errors.len(), 1, "oversized frame rejected");
    }

    #[test]
    fn bad_queue_id_immediate_error() {
        let mut f = fe();
        let err = f.submit(0.0, cmd(Opcode::Read, 77, 1, 0, 4096)).unwrap_err();
        assert_eq!(err.status, Status::InvalidField);
    }

    #[test]
    fn two_engines_pipeline_commands() {
        let mut f = fe();
        for i in 0..4 {
            f.submit(0.0, cmd(Opcode::Read, 1, i, 0, 4096)).unwrap();
        }
        let (ready, _) = f.process(0.0);
        let max_done = ready.iter().map(|(t, _)| *t).fold(0.0, f64::max);
        // 4 commands on 2 engines: 2 rounds → 10 µs, not 20 µs
        assert!((max_done - 10e-6).abs() < 1e-9, "{max_done}");
    }
}
