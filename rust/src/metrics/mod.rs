//! Metrics registry: counters, gauges, histograms, and time series keyed
//! by name, plus text/CSV/JSON report emitters. The simulator, device
//! models, scheduler, and power meter all record into a [`Metrics`]
//! instance owned by the experiment driver; benches read the same
//! counters the paper's figures plot (words/s, queries/s, bytes moved,
//! Joules).
//!
//! Hot-path recording is allocation-free: names resolve once to a
//! [`CounterId`]/[`HistogramId`] handle (or lazily on first use of the
//! string API), and values live in dense `Vec` stores indexed by those
//! handles. The scheduler's per-batch `observe` — called once per
//! dispatched batch across millions of simulated items — pre-resolves
//! its handles at run start and never touches a `String` again
//! (§Perf: the old `entry(name.to_string())` allocated per event).

use std::collections::BTreeMap;

use crate::codec::json::Json;
use crate::util::stats::{percentile_sorted, Summary, Welford};

/// A histogram with power-of-two-ish fixed buckets plus exact reservoir
/// of up to `CAP` samples for accurate percentiles in reports.
#[derive(Clone, Debug)]
pub struct Histogram {
    welford: Welford,
    samples: Vec<f64>,
    cap: usize,
    /// Number of samples dropped from the reservoir (recorded beyond cap).
    overflow: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::with_capacity(65_536)
    }
}

impl Histogram {
    pub fn with_capacity(cap: usize) -> Self {
        Histogram { welford: Welford::new(), samples: Vec::new(), cap, overflow: 0 }
    }

    pub fn record(&mut self, v: f64) {
        self.welford.push(v);
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            self.overflow += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.welford.count()
    }
    pub fn mean(&self) -> f64 {
        self.welford.mean()
    }
    pub fn std(&self) -> f64 {
        self.welford.std()
    }
    pub fn min(&self) -> f64 {
        self.welford.min()
    }
    pub fn max(&self) -> f64 {
        self.welford.max()
    }

    pub fn percentile(&self, pct: f64) -> f64 {
        let mut sorted = self.samples.clone();
        sorted.sort_by(f64::total_cmp);
        // Empty histogram → NaN, preserving this method's legacy
        // contract (callers skip zero-count histograms before reporting).
        percentile_sorted(&sorted, pct).unwrap_or(f64::NAN)
    }

    /// Every standard percentile from one sort of the reservoir. Report
    /// emitters want several percentiles per histogram; calling
    /// [`Histogram::percentile`] for each re-clones and re-sorts the
    /// full reservoir every time (§Perf: `to_json` + `report` paid four
    /// sorts of up to 65 536 samples per histogram). `None` when
    /// nothing has been recorded.
    pub fn summary(&self) -> Option<Summary> {
        Summary::of(&self.samples)
    }

    /// Reservoir samples dropped beyond the cap (their moments are
    /// still exact via Welford; only percentiles degrade to the
    /// reservoir prefix).
    pub fn overflow(&self) -> u64 {
        self.overflow
    }
}

/// A named point-in-time series (e.g. power draw over simulated time).
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub points: Vec<(f64, f64)>, // (time, value)
}

impl Series {
    pub fn push(&mut self, t: f64, v: f64) {
        self.points.push((t, v));
    }

    /// Trapezoidal integral — turns a power series (W) into energy (J).
    ///
    /// Requires the points to be in non-decreasing time order: an
    /// out-of-order point contributes a *negative*-width trapezoid and
    /// silently corrupts the total. Single-writer series are ordered by
    /// construction (simulated time only moves forward);
    /// [`Metrics::merge`] re-sorts concatenated series to restore the
    /// invariant.
    pub fn integral(&self) -> f64 {
        debug_assert!(
            self.points.windows(2).all(|w| w[0].0 <= w[1].0),
            "Series::integral requires time-ordered points"
        );
        self.points
            .windows(2)
            .map(|w| 0.5 * (w[0].1 + w[1].1) * (w[1].0 - w[0].0))
            .sum()
    }
}

/// Stable handle to a counter slot, issued by [`Metrics::counter_id`].
///
/// Valid for the lifetime of the `Metrics` that issued it (slots are
/// never removed or reordered). Using a handle from a *different*
/// registry is a logic error: it indexes whatever lives in that slot
/// there, or panics if the slot does not exist.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CounterId(usize);

/// Stable handle to a histogram slot, issued by
/// [`Metrics::histogram_id`]. Same validity rules as [`CounterId`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistogramId(usize);

/// Central metrics registry.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counter_index: BTreeMap<String, usize>,
    counter_vals: Vec<f64>,
    gauges: BTreeMap<String, f64>,
    hist_index: BTreeMap<String, usize>,
    hist_store: Vec<Histogram>,
    series: BTreeMap<String, Series>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    // ---- counters ----
    /// Resolve `name` to a dense-slot handle, creating the counter (at
    /// 0.0) if absent. Hot loops resolve once and use [`Metrics::inc_id`].
    pub fn counter_id(&mut self, name: &str) -> CounterId {
        if let Some(&i) = self.counter_index.get(name) {
            return CounterId(i);
        }
        let i = self.counter_vals.len();
        self.counter_vals.push(0.0);
        self.counter_index.insert(name.to_string(), i);
        CounterId(i)
    }

    /// Increment through a pre-resolved handle: no lookup, no allocation.
    #[inline]
    pub fn inc_id(&mut self, id: CounterId, by: f64) {
        self.counter_vals[id.0] += by;
    }

    /// Increment by name. Allocation-free when the counter already
    /// exists; the name is interned on first use.
    pub fn inc(&mut self, name: &str, by: f64) {
        if let Some(&i) = self.counter_index.get(name) {
            self.counter_vals[i] += by;
        } else {
            let id = self.counter_id(name);
            self.inc_id(id, by);
        }
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.counter_index
            .get(name)
            .map(|&i| self.counter_vals[i])
            .unwrap_or(0.0)
    }

    // ---- gauges ----
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    // ---- histograms ----
    /// Resolve `name` to a dense-slot handle, creating an empty histogram
    /// if absent. Hot loops resolve once and use [`Metrics::observe_id`].
    pub fn histogram_id(&mut self, name: &str) -> HistogramId {
        if let Some(&i) = self.hist_index.get(name) {
            return HistogramId(i);
        }
        let i = self.hist_store.len();
        self.hist_store.push(Histogram::default());
        self.hist_index.insert(name.to_string(), i);
        HistogramId(i)
    }

    /// Record through a pre-resolved handle: no lookup, no allocation
    /// (beyond the reservoir's own growth).
    #[inline]
    pub fn observe_id(&mut self, id: HistogramId, v: f64) {
        self.hist_store[id.0].record(v);
    }

    /// Record by name. Allocation-free when the histogram already exists.
    pub fn observe(&mut self, name: &str, v: f64) {
        if let Some(&i) = self.hist_index.get(name) {
            self.hist_store[i].record(v);
        } else {
            let id = self.histogram_id(name);
            self.observe_id(id, v);
        }
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.hist_index.get(name).map(|&i| &self.hist_store[i])
    }

    // ---- series ----
    pub fn sample(&mut self, name: &str, t: f64, v: f64) {
        self.series.entry(name.to_string()).or_default().push(t, v);
    }
    pub fn series(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// Merge another registry into this one (counters add, gauges take the
    /// other's values, histograms/series concatenate).
    ///
    /// Histogram overflow carries over: samples the source already
    /// dropped from its reservoir stay counted as dropped here instead
    /// of vanishing (their Welford moments are gone with the source —
    /// only the reservoir samples can be re-recorded — so the merged
    /// `count()` covers re-recorded samples while `overflow()` keeps
    /// the full drop tally). Merged series are re-sorted by time so
    /// [`Series::integral`]'s ordering invariant survives interleaved
    /// writers.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, &i) in &other.counter_index {
            self.inc(k, other.counter_vals[i]);
        }
        for (k, v) in &other.gauges {
            self.set_gauge(k, *v);
        }
        for (k, &i) in &other.hist_index {
            let id = self.histogram_id(k);
            for &s in &other.hist_store[i].samples {
                self.hist_store[id.0].record(s);
            }
            self.hist_store[id.0].overflow += other.hist_store[i].overflow;
        }
        for (k, s) in &other.series {
            let dst = self.series.entry(k.clone()).or_default();
            dst.points.extend_from_slice(&s.points);
            // Blind concatenation interleaves two ordered timelines out
            // of order; a stable sort on time restores the integral
            // invariant without reordering same-timestamp points.
            dst.points.sort_by(|a, b| a.0.total_cmp(&b.0));
        }
    }

    /// Render counters and histogram summaries as a JSON object.
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        let mut counters = Json::obj();
        for (k, &i) in &self.counter_index {
            counters.set(k, self.counter_vals[i].into());
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges.set(k, (*v).into());
        }
        let mut hists = Json::obj();
        for (k, &i) in &self.hist_index {
            let h = &self.hist_store[i];
            // Pre-registered but never-recorded histograms (id handles
            // are created eagerly) would emit NaN percentiles; skip
            // them. A non-zero count means the reservoir is non-empty
            // (it fills before overflow starts), so the summary exists.
            let s = match h.summary() {
                Some(s) => s,
                None => continue,
            };
            let mut o = Json::obj();
            o.set("count", (h.count() as f64).into())
                .set("mean", h.mean().into())
                .set("p50", s.p50.into())
                .set("p99", s.p99.into())
                .set("max", h.max().into());
            hists.set(k, o);
        }
        root.set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists);
        root
    }

    /// Human-readable dump, sorted by key.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, &i) in &self.counter_index {
            let v = self.counter_vals[i];
            out.push_str(&format!("{k:<48} {v:>16.3}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("{k:<48} {v:>16.3} (gauge)\n"));
        }
        for (k, &i) in &self.hist_index {
            let h = &self.hist_store[i];
            let s = match h.summary() {
                Some(s) => s,
                None => continue,
            };
            out.push_str(&format!(
                "{k:<48} n={} mean={:.4} p50={:.4} p99={:.4}\n",
                h.count(),
                h.mean(),
                s.p50,
                s.p99
            ));
        }
        out
    }
}

/// Fixed-width text table builder used by experiment drivers to print the
/// paper's figure/table rows.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                line.push_str(&format!("{:>w$}  ", cells[i], w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.inc("io.bytes", 100.0);
        m.inc("io.bytes", 28.0);
        assert_eq!(m.counter("io.bytes"), 128.0);
        assert_eq!(m.counter("missing"), 0.0);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.5).abs() < 1.0);
        assert_eq!(h.max(), 100.0);
    }

    #[test]
    fn histogram_reservoir_overflow_keeps_welford_exact() {
        let mut h = Histogram::with_capacity(10);
        for i in 0..1000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 499.5).abs() < 1e-9);
        assert_eq!(h.overflow, 990);
    }

    #[test]
    fn series_integral_constant_power() {
        let mut s = Series::default();
        s.push(0.0, 100.0);
        s.push(10.0, 100.0);
        assert!((s.integral() - 1000.0).abs() < 1e-9); // 100 W × 10 s
    }

    #[test]
    fn merge_combines() {
        let mut a = Metrics::new();
        a.inc("x", 1.0);
        a.observe("lat", 5.0);
        let mut b = Metrics::new();
        b.inc("x", 2.0);
        b.observe("lat", 7.0);
        a.merge(&b);
        assert_eq!(a.counter("x"), 3.0);
        assert_eq!(a.histogram("lat").unwrap().count(), 2);
    }

    #[test]
    fn merge_carries_histogram_overflow() {
        // A source reservoir that already dropped samples must not have
        // those drops vanish in the merge: overflow tallies add.
        let mut b = Metrics::new();
        let id = b.histogram_id("lat");
        b.hist_store[id.0] = Histogram::with_capacity(4);
        for i in 0..10 {
            b.observe("lat", i as f64);
        }
        assert_eq!(b.histogram("lat").unwrap().overflow(), 6);
        let mut a = Metrics::new();
        a.observe("lat", 99.0);
        a.merge(&b);
        let h = a.histogram("lat").unwrap();
        assert_eq!(h.overflow(), 6, "source overflow must carry over");
        assert_eq!(h.count(), 5, "1 local + 4 reservoir samples re-recorded");
    }

    #[test]
    fn merge_restores_series_time_order() {
        // Two ordered timelines interleave out of order under blind
        // concatenation; merge must re-sort so integral() stays valid.
        let mut a = Metrics::new();
        a.sample("p", 0.0, 100.0);
        a.sample("p", 10.0, 100.0);
        let mut b = Metrics::new();
        b.sample("p", 5.0, 100.0);
        b.sample("p", 15.0, 100.0);
        a.merge(&b);
        let s = a.series("p").unwrap();
        assert!(s.points.windows(2).all(|w| w[0].0 <= w[1].0));
        assert!((s.integral() - 1500.0).abs() < 1e-9); // 100 W × 15 s
    }

    #[test]
    fn summary_matches_per_call_percentiles() {
        let mut h = Histogram::default();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        let s = h.summary().unwrap();
        assert_eq!(s.p50.to_bits(), h.percentile(50.0).to_bits());
        assert_eq!(s.p99.to_bits(), h.percentile(99.0).to_bits());
        assert_eq!(s.p999.to_bits(), h.percentile(99.9).to_bits());
        assert!(Histogram::default().summary().is_none());
    }

    #[test]
    fn table_renders_and_csv() {
        let mut t = Table::new("Fig 5(a)", &["batch", "csds", "words/s"]);
        t.row(vec!["6".into(), "36".into(), "296.0".into()]);
        let txt = t.render();
        assert!(txt.contains("Fig 5(a)"));
        assert!(txt.contains("296.0"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("batch,csds,words/s"));
    }

    #[test]
    fn id_handles_alias_the_named_slots() {
        let mut m = Metrics::new();
        let c = m.counter_id("sched.items");
        let h = m.histogram_id("sched.lat");
        // handles are stable across later interning of other names
        m.inc("other.counter", 1.0);
        m.observe("other.hist", 2.0);
        m.inc_id(c, 5.0);
        m.inc_id(c, 7.0);
        m.inc("sched.items", 8.0);
        assert_eq!(m.counter("sched.items"), 20.0);
        m.observe_id(h, 1.0);
        m.observe_id(h, 3.0);
        m.observe("sched.lat", 5.0);
        let hist = m.histogram("sched.lat").unwrap();
        assert_eq!(hist.count(), 3);
        assert!((hist.mean() - 3.0).abs() < 1e-12);
        // resolving the same name again returns the same slot
        assert_eq!(m.counter_id("sched.items"), c);
        assert_eq!(m.histogram_id("sched.lat"), h);
    }

    #[test]
    fn empty_preregistered_histograms_stay_out_of_reports() {
        let mut m = Metrics::new();
        let _ = m.histogram_id("never.recorded");
        m.observe("real", 1.0);
        let j = m.to_json();
        assert!(j.at(&["histograms", "never.recorded"]).is_none());
        assert!(j.at(&["histograms", "real", "count"]).is_some());
        assert!(!m.report().contains("never.recorded"));
        // but the slot exists and is queryable
        assert_eq!(m.histogram("never.recorded").unwrap().count(), 0);
    }

    #[test]
    fn json_export_parses() {
        let mut m = Metrics::new();
        m.inc("q", 42.0);
        m.observe("lat", 1.0);
        let j = m.to_json();
        assert_eq!(j.at(&["counters", "q"]).unwrap().as_f64(), Some(42.0));
        assert_eq!(j.at(&["histograms", "lat", "count"]).unwrap().as_u64(), Some(1));
    }
}
