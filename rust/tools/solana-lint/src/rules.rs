//! The D1–D6 determinism rules, plus the always-on `bad-marker`
//! meta-rule. Each rule is a token-pattern matcher; see the README
//! "Static analysis" section for the invariant each one protects.

use crate::lexer::{match_seq, Comment, Kind, Tok};

/// Rule identifiers, in D1..D6 order. `bad-marker` is reported by the
/// marker parser itself and cannot be suppressed.
pub const RULES: [&str; 6] = [
    "hash-iter",
    "wall-clock",
    "rng-gate",
    "no-unwrap",
    "lossy-cast",
    "join-reduce",
];

const HASH_TYPES: [&str; 4] = ["HashMap", "HashSet", "FastMap", "FastSet"];
const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];
/// The draw methods of `util::rng::Rng` (forks/constructors excluded:
/// building a generator is fine, consuming entropy is what must be
/// gated).
const DRAW_METHODS: [&str; 12] = [
    "next_u64",
    "f64",
    "range_f64",
    "below",
    "range_u64",
    "chance",
    "gaussian",
    "gaussian_trunc",
    "exponential",
    "zipf",
    "shuffle",
    "choose",
];
/// Identifier names that mean "this is an item/byte counter" (the PR-2
/// u64-overflow bug class rode exactly these).
const COUNTER_WORDS: [&str; 11] = [
    "items",
    "bytes",
    "len",
    "count",
    "counts",
    "requests",
    "total",
    "remaining",
    "offered",
    "accepted",
    "shed",
];
const NARROW_TYPES: [&str; 6] = ["u8", "u16", "u32", "i8", "i16", "i32"];

#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub msg: String,
}

fn is_punct(t: &Tok, text: &str) -> bool {
    t.kind == Kind::Punct && t.text == text
}

fn is_ident(t: &Tok, text: &str) -> bool {
    t.kind == Kind::Ident && t.text == text
}

/// Line ranges `(start, end)` covered by `#[cfg(test)]` items or
/// `#[test]` functions. D4/D5/D6 skip these; test code may unwrap.
pub fn test_regions(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut regions = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    let cfg_test: [(Kind, Option<&str>); 7] = [
        (Kind::Punct, Some("#")),
        (Kind::Punct, Some("[")),
        (Kind::Ident, Some("cfg")),
        (Kind::Punct, Some("(")),
        (Kind::Ident, Some("test")),
        (Kind::Punct, Some(")")),
        (Kind::Punct, Some("]")),
    ];
    let test_attr: [(Kind, Option<&str>); 4] = [
        (Kind::Punct, Some("#")),
        (Kind::Punct, Some("[")),
        (Kind::Ident, Some("test")),
        (Kind::Punct, Some("]")),
    ];
    while i < n {
        let is_cfg_test = match_seq(toks, i, &cfg_test);
        let is_test_attr = !is_cfg_test && match_seq(toks, i, &test_attr);
        if !(is_cfg_test || is_test_attr) {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        let mut j = i + if is_cfg_test { 7 } else { 4 };
        // Skip any further attributes on the same item.
        while j < n && is_punct(&toks[j], "#") {
            j += 1;
            if j < n && is_punct(&toks[j], "[") {
                let mut depth = 1usize;
                j += 1;
                while j < n && depth > 0 {
                    if is_punct(&toks[j], "[") {
                        depth += 1;
                    } else if is_punct(&toks[j], "]") {
                        depth -= 1;
                    }
                    j += 1;
                }
            }
        }
        // Find the item's opening brace; a `;` first means no body.
        while j < n && !(is_punct(&toks[j], "{") || is_punct(&toks[j], ";")) {
            j += 1;
        }
        if j >= n || is_punct(&toks[j], ";") {
            i = j + 1;
            continue;
        }
        let mut depth = 1usize;
        j += 1;
        while j < n && depth > 0 {
            if is_punct(&toks[j], "{") {
                depth += 1;
            } else if is_punct(&toks[j], "}") {
                depth -= 1;
            }
            j += 1;
        }
        let end_line = if j > 0 { toks[j - 1].line } else { start_line };
        regions.push((start_line, end_line));
        i = j;
    }
    regions
}

fn in_regions(regions: &[(u32, u32)], line: u32) -> bool {
    regions.iter().any(|&(a, b)| a <= line && line <= b)
}

/// Parsed suppression state for one file.
pub struct Markers {
    /// rule -> lines it is allowed on (the marker's line and the next).
    line_allows: Vec<(&'static str, u32)>,
    /// rules allowed file-wide via `allow-file`.
    file_allows: Vec<&'static str>,
    /// malformed markers: (line, message).
    pub bad: Vec<(u32, String)>,
}

impl Markers {
    pub fn allows(&self, rule: &str, line: u32) -> bool {
        self.file_allows.iter().any(|r| *r == rule)
            || self
                .line_allows
                .iter()
                .any(|(r, l)| *r == rule && (*l == line || *l + 1 == line))
    }
}

/// Parse `// solana-lint: allow(<rule>, reason = "...")` markers out of
/// the comment list. Anything that mentions `solana-lint:` but does not
/// parse — or names an unknown rule, or omits the reason — is reported
/// as `bad-marker` (unsuppressable: a broken suppression must never
/// silently widen the net).
pub fn parse_markers(comments: &[Comment]) -> Markers {
    let mut m = Markers {
        line_allows: Vec::new(),
        file_allows: Vec::new(),
        bad: Vec::new(),
    };
    for c in comments {
        if !c.text.contains("solana-lint:") {
            continue;
        }
        match parse_marker_text(&c.text) {
            None => m
                .bad
                .push((c.line, "unparseable solana-lint marker".to_string())),
            Some((file_wide, rule, reason)) => {
                let Some(known) = RULES.iter().find(|r| **r == rule) else {
                    m.bad
                        .push((c.line, format!("marker names unknown rule '{rule}'")));
                    continue;
                };
                match reason {
                    Some(r) if !r.trim().is_empty() => {
                        if file_wide {
                            m.file_allows.push(known);
                        } else {
                            m.line_allows.push((known, c.line));
                        }
                    }
                    _ => m
                        .bad
                        .push((c.line, format!("marker for '{rule}' is missing a reason"))),
                }
            }
        }
    }
    m
}

/// Try to parse a marker anywhere in `text`. Returns
/// `(is_allow_file, rule, reason)` for the first occurrence of
/// `solana-lint:` that parses; `None` if none does.
fn parse_marker_text(text: &str) -> Option<(bool, String, Option<String>)> {
    let needle = "solana-lint:";
    let mut from = 0usize;
    while let Some(pos) = text[from..].find(needle) {
        let start = from + pos + needle.len();
        if let Some(parsed) = parse_marker_at(&text[start..]) {
            return Some(parsed);
        }
        from = start;
    }
    None
}

fn parse_marker_at(s: &str) -> Option<(bool, String, Option<String>)> {
    let mut rest = s.trim_start();
    let file_wide = if let Some(r) = rest.strip_prefix("allow-file") {
        rest = r;
        true
    } else if let Some(r) = rest.strip_prefix("allow") {
        rest = r;
        false
    } else {
        return None;
    };
    rest = rest.strip_prefix('(')?.trim_start();
    let rule_len = rest
        .find(|c: char| !(c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'))
        .unwrap_or(rest.len());
    if rule_len == 0 {
        return None;
    }
    let rule = rest[..rule_len].to_string();
    rest = rest[rule_len..].trim_start();
    let mut reason = None;
    if let Some(r) = rest.strip_prefix(',') {
        rest = r.trim_start().strip_prefix("reason")?.trim_start();
        rest = rest.strip_prefix('=')?.trim_start();
        rest = rest.strip_prefix('"')?;
        let end = rest.find('"')?;
        reason = Some(rest[..end].to_string());
        rest = rest[end + 1..].trim_start();
    }
    rest.strip_prefix(')')?;
    Some((file_wide, rule, reason))
}

/// Names declared (by `name: HashType<..>` or `name = HashType::..`)
/// as hash-backed collections in this file.
fn hash_names(toks: &[Tok]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    let n = toks.len();
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident || !HASH_TYPES.contains(&t.text.as_str()) {
            continue;
        }
        // Scan back for `name :` or `name =` within the statement.
        let mut j = i.saturating_sub(1);
        let mut guard = 0usize;
        while j > 0 && guard < 64 {
            guard += 1;
            let tj = &toks[j];
            if tj.kind == Kind::Punct && (tj.text == ";" || tj.text == "{" || tj.text == "}") {
                break;
            }
            if tj.kind == Kind::Punct
                && (tj.text == ":" || tj.text == "=")
                && toks[j - 1].kind == Kind::Ident
            {
                // Skip `::` path segments like std::collections::HashMap.
                if tj.text == ":" && j + 1 < n && is_punct(&toks[j + 1], ":") {
                    j -= 1;
                    continue;
                }
                let name = toks[j - 1].text.clone();
                if !names.contains(&name) {
                    names.push(name);
                }
                break;
            }
            j -= 1;
        }
    }
    names
}

/// The identifier a method call is invoked on: the token before the
/// `.` at `dot_i`, skipping one `(...)`-closed call group.
fn receiver_name(toks: &[Tok], dot_i: usize) -> Option<String> {
    let mut j = dot_i.checked_sub(1)?;
    if is_punct(&toks[j], ")") {
        let mut depth = 1usize;
        loop {
            j = j.checked_sub(1)?;
            if is_punct(&toks[j], ")") {
                depth += 1;
            } else if is_punct(&toks[j], "(") {
                depth -= 1;
                if depth == 0 {
                    j = j.checked_sub(1)?;
                    break;
                }
            }
        }
    }
    if toks[j].kind == Kind::Ident {
        Some(toks[j].text.clone())
    } else {
        None
    }
}

fn path_components(path: &str) -> Vec<&str> {
    path.split(['/', '\\']).collect()
}

/// D1: no iteration over hash-backed collections. Keyed lookup is
/// fine; iteration order is nondeterministic and reaches reports.
pub fn rule_hash_iter(_path: &str, toks: &[Tok], findings: &mut Vec<Finding>) {
    let names = hash_names(toks);
    let n = toks.len();
    for i in 0..n {
        let t = &toks[i];
        if is_punct(t, ".") && i + 1 < n {
            let m = &toks[i + 1];
            if m.kind == Kind::Ident && ITER_METHODS.contains(&m.text.as_str()) {
                if let Some(recv) = receiver_name(toks, i) {
                    if names.contains(&recv) {
                        findings.push(Finding {
                            rule: "hash-iter",
                            file: String::new(),
                            line: m.line,
                            col: m.col,
                            msg: format!(
                                "iteration over hash collection `{recv}.{}()` — order is \
                                 nondeterministic; use BTreeMap/BTreeSet or util::sorted_* \
                                 (keyed lookup is fine)",
                                m.text
                            ),
                        });
                    }
                }
            }
        }
        if is_ident(t, "for") {
            // `for PAT in [&][mut][self.]NAME {`
            let mut j = i + 1;
            let mut depth = 0i32;
            let mut guard = 0usize;
            let mut found_in = false;
            while j < n && guard < 64 {
                guard += 1;
                if is_ident(&toks[j], "in") && depth == 0 {
                    found_in = true;
                    break;
                }
                if toks[j].kind == Kind::Punct {
                    if toks[j].text == "(" || toks[j].text == "[" {
                        depth += 1;
                    } else if toks[j].text == ")" || toks[j].text == "]" {
                        depth -= 1;
                    }
                }
                j += 1;
            }
            if !found_in {
                continue;
            }
            let mut k = j + 1;
            while k < n
                && (is_punct(&toks[k], "&")
                    || is_ident(&toks[k], "mut")
                    || is_ident(&toks[k], "self")
                    || is_punct(&toks[k], "."))
            {
                k += 1;
            }
            if k + 1 < n
                && toks[k].kind == Kind::Ident
                && names.contains(&toks[k].text)
                && is_punct(&toks[k + 1], "{")
            {
                findings.push(Finding {
                    rule: "hash-iter",
                    file: String::new(),
                    line: toks[k].line,
                    col: toks[k].col,
                    msg: format!(
                        "for-loop over hash collection `{}` — order is nondeterministic; \
                         use BTreeMap/BTreeSet or util::sorted_* (keyed lookup is fine)",
                        toks[k].text
                    ),
                });
            }
        }
    }
}

/// D2: no wall-clock reads. The simulator runs on virtual time;
/// sanctioned real-time sites (`sched::live`, `bench_support`) carry
/// explicit allow markers instead of a path exemption, so every
/// wall-clock read in the tree is visibly justified.
pub fn rule_wall_clock(_path: &str, toks: &[Tok], findings: &mut Vec<Finding>) {
    let now_seq: [(Kind, Option<&str>); 3] = [
        (Kind::Punct, Some(":")),
        (Kind::Punct, Some(":")),
        (Kind::Ident, Some("now")),
    ];
    for (i, t) in toks.iter().enumerate() {
        if t.kind == Kind::Ident
            && (t.text == "Instant" || t.text == "SystemTime")
            && match_seq(toks, i + 1, &now_seq)
        {
            findings.push(Finding {
                rule: "wall-clock",
                file: String::new(),
                line: t.line,
                col: t.col,
                msg: format!(
                    "wall-clock read `{}::now()` — virtual time only in simulator paths; \
                     real-time call sites need an allow marker",
                    t.text
                ),
            });
        }
    }
}

/// D3: inside `faults/` and `traffic/`, every RNG draw must be
/// dominated by a `rate > 0.0`-style guard (a quiet plan must never
/// touch the RNG — PR 6's quiet-plan ≡ no-plan bit-identity contract).
pub fn rule_rng_gate(path: &str, toks: &[Tok], findings: &mut Vec<Finding>) {
    let parts = path_components(path);
    if !parts.contains(&"faults") && !parts.contains(&"traffic") {
        return;
    }
    let n = toks.len();
    // Each `{` pushes whether its opening condition carried a `> <num>`
    // comparison; a draw is guarded if any enclosing block (or the
    // condition currently being scanned) did.
    let mut stack: Vec<bool> = Vec::new();
    let mut pending: Option<bool> = None;
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        if (is_ident(t, "if") || is_ident(t, "while")) && pending.is_none() {
            pending = Some(false);
        } else if is_punct(t, "{") {
            stack.push(pending.take().unwrap_or(false));
        } else if is_punct(t, "}") {
            stack.pop();
        } else if pending.is_some() && is_punct(t, ">") {
            if i + 1 < n && toks[i + 1].kind == Kind::Num {
                pending = Some(true);
            }
        }
        if is_punct(t, ".") && i + 2 < n {
            let m = &toks[i + 1];
            if m.kind == Kind::Ident
                && DRAW_METHODS.contains(&m.text.as_str())
                && is_punct(&toks[i + 2], "(")
            {
                if let Some(recv) = receiver_name(toks, i) {
                    if recv.to_ascii_lowercase().contains("rng") {
                        let guarded =
                            stack.iter().any(|g| *g) || matches!(pending, Some(true));
                        if !guarded {
                            findings.push(Finding {
                                rule: "rng-gate",
                                file: String::new(),
                                line: m.line,
                                col: m.col,
                                msg: format!(
                                    "RNG draw `{recv}.{}()` not dominated by a `rate > 0.0`-style \
                                     guard — quiet fault/traffic plans must never touch the RNG",
                                    m.text
                                ),
                            });
                        }
                    }
                }
            }
        }
        i += 1;
    }
}

/// D4: no `unwrap()`/`expect()`/`panic!` in non-test library code.
pub fn rule_no_unwrap(
    _path: &str,
    toks: &[Tok],
    regions: &[(u32, u32)],
    findings: &mut Vec<Finding>,
) {
    let n = toks.len();
    for i in 0..n {
        let t = &toks[i];
        if in_regions(regions, t.line) {
            continue;
        }
        if is_punct(t, ".") && i + 2 < n {
            let m = &toks[i + 1];
            if m.kind == Kind::Ident
                && (m.text == "unwrap" || m.text == "expect")
                && toks[i + 2].text == "("
            {
                findings.push(Finding {
                    rule: "no-unwrap",
                    file: String::new(),
                    line: m.line,
                    col: m.col,
                    msg: format!(
                        "`.{}()` in non-test library code — return anyhow::Error (or mark \
                         genuinely-infallible sites with an allow marker and a reason)",
                        m.text
                    ),
                });
            }
        }
        if is_ident(t, "panic") && i + 1 < n && is_punct(&toks[i + 1], "!") {
            findings.push(Finding {
                rule: "no-unwrap",
                file: String::new(),
                line: t.line,
                col: t.col,
                msg: "`panic!` in non-test library code — return anyhow::Error".to_string(),
            });
        }
    }
}

/// D5: no lossy `as` narrowing casts on item/byte counters (the PR-2
/// u64-overflow class: `items as u32` truncates past ~2^32 items).
pub fn rule_lossy_cast(
    _path: &str,
    toks: &[Tok],
    regions: &[(u32, u32)],
    findings: &mut Vec<Finding>,
) {
    let n = toks.len();
    for i in 0..n {
        let t = &toks[i];
        if !is_ident(t, "as") || i + 1 >= n {
            continue;
        }
        if in_regions(regions, t.line) {
            continue;
        }
        let ty = &toks[i + 1];
        if ty.kind != Kind::Ident || !NARROW_TYPES.contains(&ty.text.as_str()) {
            continue;
        }
        if let Some(recv) = receiver_name(toks, i) {
            if COUNTER_WORDS.contains(&recv.as_str()) {
                findings.push(Finding {
                    rule: "lossy-cast",
                    file: String::new(),
                    line: t.line,
                    col: t.col,
                    msg: format!(
                        "lossy narrowing `{recv} as {}` on an item/byte counter — the PR-2 \
                         u64-overflow class; widen or bounds-check first",
                        ty.text
                    ),
                });
            }
        }
    }
}

/// D6: threads may only be spawned by the deterministic `exp::pool`
/// reduction (float accumulation order across joins must be fixed).
pub fn rule_join_reduce(
    path: &str,
    toks: &[Tok],
    regions: &[(u32, u32)],
    findings: &mut Vec<Finding>,
) {
    let parts = path_components(path);
    if parts.len() >= 2 && parts[parts.len() - 2] == "exp" && parts[parts.len() - 1] == "pool.rs" {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if in_regions(regions, t.line) {
            continue;
        }
        if is_ident(t, "thread")
            && match_seq(
                toks,
                i + 1,
                &[
                    (Kind::Punct, Some(":")),
                    (Kind::Punct, Some(":")),
                    (Kind::Ident, None),
                ],
            )
        {
            let what = &toks[i + 3].text;
            if what == "spawn" || what == "scope" || what == "Builder" {
                findings.push(Finding {
                    rule: "join-reduce",
                    file: String::new(),
                    line: t.line,
                    col: t.col,
                    msg: format!(
                        "`thread::{what}` outside exp::pool — cross-thread float accumulation \
                         must go through the deterministic exp::pool reduction (mark sanctioned \
                         sites with a reason)"
                    ),
                });
            }
        }
    }
}
