//! Shared-disk file system (OCFS2 analogue).
//!
//! §III-B: "We use the Oracle Cluster File System (OCFS2) to enable
//! sharing file systems and mounting the same partitions from both the
//! host and the ISP. OCFS2 requires a TCP/IP communication link to
//! orchestrate and update file systems of the two mounting points."
//!
//! This module implements the pieces that matter for the paper's
//! experiments: an extent-based on-disk layout (so file reads become
//! physical extent reads against the FTL), an inode namespace shared by
//! two mount points, and a distributed lock manager whose *lock mastering
//! traffic crosses the TCP/IP tunnel* — the cost the scheduler avoids by
//! shipping only indexes. Lock caching mirrors OCFS2's behaviour: a node
//! holding a cached lock re-acquires it for free until the other node
//! forces a downgrade.

use std::collections::BTreeMap;

use crate::interconnect::TcpTunnel;
use crate::sim::SimTime;
use crate::util::div_ceil;

/// Which mount point is acting (§III-B: host and ISP mount the same
/// partition).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mount {
    Host,
    Isp,
}

/// A contiguous run of file-system blocks on the device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Extent {
    /// First byte address on the logical device.
    pub start_byte: u64,
    pub bytes: u64,
}

/// Lock modes for the per-inode DLM lock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LockMode {
    /// Protected read — shareable.
    Read,
    /// Exclusive — required for writes.
    Write,
}

#[derive(Clone, Debug, Default)]
struct DlmLock {
    /// Protected-read cache per mount (host, isp) — OCFS2 PR locks are
    /// shareable, so both mounts can hold a cached read lock at once.
    read_cached: [bool; 2],
    /// Exclusive holder, if any (implies the right to read too).
    write_holder: Option<Mount>,
}

fn mount_idx(m: Mount) -> usize {
    match m {
        Mount::Host => 0,
        Mount::Isp => 1,
    }
}

/// An inode: size + extent list + its DLM lock.
#[derive(Clone, Debug)]
pub struct Inode {
    pub size: u64,
    pub extents: Vec<Extent>,
    lock: DlmLock,
}

/// DLM traffic statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DlmStats {
    pub acquisitions: u64,
    pub cached_hits: u64,
    pub remote_grants: u64,
    pub messages: u64,
}

/// The shared file system on one CSD partition.
pub struct SharedFs {
    /// FS block size (OCFS2 default cluster size class).
    pub block_bytes: u64,
    /// Device capacity in bytes.
    pub capacity: u64,
    /// Next free byte (extent allocator is first-fit bump + free list).
    next_free: u64,
    free_list: Vec<Extent>,
    inodes: BTreeMap<String, Inode>,
    pub dlm: DlmStats,
}

impl SharedFs {
    pub fn new(capacity: u64, block_bytes: u64) -> SharedFs {
        assert!(block_bytes.is_power_of_two());
        SharedFs {
            block_bytes,
            capacity,
            next_free: 0,
            free_list: Vec::new(),
            inodes: BTreeMap::new(),
            dlm: DlmStats::default(),
        }
    }

    fn round_up(&self, bytes: u64) -> u64 {
        div_ceil(bytes.max(1), self.block_bytes) * self.block_bytes
    }

    /// Create a file of `size` bytes; allocates extents. Returns an error
    /// if the name exists or space is exhausted.
    pub fn create(&mut self, name: &str, size: u64) -> anyhow::Result<()> {
        if self.inodes.contains_key(name) {
            anyhow::bail!("file exists: {name}");
        }
        let need = self.round_up(size);
        let mut extents = Vec::new();
        let mut remaining = need;
        // First-fit from the free list.
        let mut i = 0;
        while remaining > 0 && i < self.free_list.len() {
            let e = self.free_list[i];
            let take = e.bytes.min(remaining);
            extents.push(Extent { start_byte: e.start_byte, bytes: take });
            if take == e.bytes {
                self.free_list.remove(i);
            } else {
                self.free_list[i] = Extent { start_byte: e.start_byte + take, bytes: e.bytes - take };
                i += 1;
            }
            remaining -= take;
        }
        if remaining > 0 {
            if self.next_free + remaining > self.capacity {
                // roll back free-list takes
                for e in extents {
                    self.free_list.push(e);
                }
                anyhow::bail!("no space for {name}: need {need} bytes");
            }
            extents.push(Extent { start_byte: self.next_free, bytes: remaining });
            self.next_free += remaining;
        }
        self.inodes.insert(
            name.to_string(),
            Inode { size, extents, lock: DlmLock::default() },
        );
        Ok(())
    }

    /// Delete a file, returning its extents to the free list.
    pub fn unlink(&mut self, name: &str) -> anyhow::Result<()> {
        let inode = self
            .inodes
            .remove(name)
            .ok_or_else(|| anyhow::anyhow!("no such file: {name}"))?;
        self.free_list.extend(inode.extents);
        Ok(())
    }

    pub fn stat(&self, name: &str) -> Option<(u64, usize)> {
        self.inodes.get(name).map(|i| (i.size, i.extents.len()))
    }

    pub fn exists(&self, name: &str) -> bool {
        self.inodes.contains_key(name)
    }

    /// Acquire the inode's DLM lock from `mount` at `now`.
    ///
    /// OCFS2 semantics (simplified to two mounts): a lock cached by this
    /// mount in a compatible mode is free; anything else masters the lock
    /// over the tunnel (one request/grant round trip) and possibly
    /// revokes the peer's cache. Returns the grant time.
    pub fn lock(
        &mut self,
        now: SimTime,
        tunnel: &mut TcpTunnel,
        name: &str,
        mount: Mount,
        mode: LockMode,
    ) -> anyhow::Result<SimTime> {
        let inode = self
            .inodes
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("lock on missing file: {name}"))?;
        self.dlm.acquisitions += 1;
        let l = &mut inode.lock;
        let cached = match mode {
            LockMode::Read => {
                l.read_cached[mount_idx(mount)] || l.write_holder == Some(mount)
            }
            LockMode::Write => l.write_holder == Some(mount),
        };
        if cached {
            self.dlm.cached_hits += 1;
            return Ok(now);
        }
        // Remote mastering: request + grant over the tunnel (~64 B each).
        let granted = tunnel.round_trip(now, 64, 64);
        self.dlm.remote_grants += 1;
        self.dlm.messages += 2;
        match mode {
            LockMode::Read => {
                l.read_cached[mount_idx(mount)] = true;
                // A peer's exclusive lock is downgraded by the grant.
                if l.write_holder.is_some() && l.write_holder != Some(mount) {
                    l.write_holder = None;
                }
            }
            LockMode::Write => {
                l.write_holder = Some(mount);
                // Revoke the peer's read cache.
                let peer = 1 - mount_idx(mount);
                l.read_cached[peer] = false;
            }
        }
        Ok(granted)
    }

    /// Map a byte range of a file to device extents for the FCU.
    /// Returns `(device_byte_offset, bytes)` runs covering the range.
    pub fn map_range(&self, name: &str, offset: u64, len: u64) -> anyhow::Result<Vec<(u64, u64)>> {
        let inode = self
            .inodes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no such file: {name}"))?;
        if offset + len > self.round_up(inode.size) {
            anyhow::bail!(
                "read past EOF: {name} offset {offset} len {len} size {}",
                inode.size
            );
        }
        let mut runs = Vec::new();
        let mut file_pos = 0u64;
        let mut remaining = len;
        let mut start = offset;
        for e in &inode.extents {
            let e_end = file_pos + e.bytes;
            if start < e_end && remaining > 0 {
                let within = start - file_pos;
                let take = (e.bytes - within).min(remaining);
                runs.push((e.start_byte + within, take));
                remaining -= take;
                start += take;
            }
            file_pos = e_end;
            if remaining == 0 {
                break;
            }
        }
        if remaining > 0 {
            anyhow::bail!("extent map incomplete for {name}");
        }
        Ok(runs)
    }

    /// Bytes currently allocated (for tests / reports).
    pub fn allocated_bytes(&self) -> u64 {
        self.inodes
            .values()
            .flat_map(|i| i.extents.iter())
            .map(|e| e.bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, forall};

    fn fs() -> SharedFs {
        SharedFs::new(1 << 30, 4096)
    }

    #[test]
    fn create_stat_unlink() {
        let mut f = fs();
        f.create("corpus.bin", 10_000).unwrap();
        let (size, extents) = f.stat("corpus.bin").unwrap();
        assert_eq!(size, 10_000);
        assert_eq!(extents, 1);
        assert_eq!(f.allocated_bytes(), 12_288); // rounded to 3 blocks
        f.unlink("corpus.bin").unwrap();
        assert!(!f.exists("corpus.bin"));
    }

    #[test]
    fn duplicate_create_fails() {
        let mut f = fs();
        f.create("a", 1).unwrap();
        assert!(f.create("a", 1).is_err());
    }

    #[test]
    fn out_of_space_fails_cleanly() {
        let mut f = SharedFs::new(8192, 4096);
        f.create("a", 8192).unwrap();
        assert!(f.create("b", 1).is_err());
        f.unlink("a").unwrap();
        f.create("b", 4096).unwrap(); // reuses freed extent
        let (_, ext) = f.stat("b").unwrap();
        assert_eq!(ext, 1);
    }

    #[test]
    fn map_range_single_extent() {
        let mut f = fs();
        f.create("x", 100_000).unwrap();
        let runs = f.map_range("x", 5_000, 10_000).unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].1, 10_000);
    }

    #[test]
    fn map_range_across_fragmented_extents() {
        let mut f = SharedFs::new(1 << 20, 4096);
        // Fragment: a(2 blocks) b(1 block) then free a → c straddles.
        f.create("a", 8192).unwrap();
        f.create("b", 4096).unwrap();
        f.unlink("a").unwrap();
        f.create("c", 16384).unwrap(); // 8 KiB from free list + 8 KiB bump
        let (_, extents) = f.stat("c").unwrap();
        assert_eq!(extents, 2);
        let runs = f.map_range("c", 4096, 8192).unwrap();
        assert_eq!(runs.iter().map(|r| r.1).sum::<u64>(), 8192);
        assert_eq!(runs.len(), 2, "straddles the extent boundary");
    }

    #[test]
    fn read_past_eof_rejected() {
        let mut f = fs();
        f.create("x", 4096).unwrap();
        assert!(f.map_range("x", 0, 8192).is_err());
    }

    #[test]
    fn dlm_lock_caching() {
        let mut f = fs();
        let mut tun = TcpTunnel::default();
        f.create("data", 4096).unwrap();
        // First acquisition masters over the tunnel.
        let t1 = f.lock(0.0, &mut tun, "data", Mount::Isp, LockMode::Read).unwrap();
        assert!(t1 > 0.0);
        assert_eq!(f.dlm.remote_grants, 1);
        // Second from the same mount: cached, free.
        let t2 = f.lock(t1, &mut tun, "data", Mount::Isp, LockMode::Read).unwrap();
        assert_eq!(t2, t1);
        assert_eq!(f.dlm.cached_hits, 1);
        // Host steals it: tunnel round trip again.
        let t3 = f.lock(t2, &mut tun, "data", Mount::Host, LockMode::Write).unwrap();
        assert!(t3 > t2);
        assert_eq!(f.dlm.remote_grants, 2);
        assert_eq!(tun.messages(), 4);
    }

    #[test]
    fn write_lock_allows_read_by_holder() {
        let mut f = fs();
        let mut tun = TcpTunnel::default();
        f.create("data", 4096).unwrap();
        f.lock(0.0, &mut tun, "data", Mount::Host, LockMode::Write).unwrap();
        let t = f.lock(1.0, &mut tun, "data", Mount::Host, LockMode::Read).unwrap();
        assert_eq!(t, 1.0, "write holder reads for free");
    }

    #[test]
    fn property_map_range_covers_exactly() {
        forall("fs map_range covers requested bytes", 100, |g| {
            let mut f = SharedFs::new(1 << 22, 4096);
            // create a few files with churn to fragment
            let n = g.usize(1..=6);
            for i in 0..n {
                let sz = g.u64(1..=100_000);
                f.create(&format!("f{i}"), sz).map_err(|e| e.to_string())?;
                if g.bool() && i > 0 {
                    let _ = f.unlink(&format!("f{}", i - 1));
                }
            }
            let sz = g.u64(4096..=200_000);
            f.create("target", sz).map_err(|e| e.to_string())?;
            let off = g.u64(0..=sz - 1);
            let len = g.u64(1..=sz - off);
            let runs = f.map_range("target", off, len).map_err(|e| e.to_string())?;
            let total: u64 = runs.iter().map(|r| r.1).sum();
            check(total == len, format!("covered {total} != requested {len}"))?;
            // runs must fall inside the device
            for (start, bytes) in runs {
                check(start + bytes <= 1 << 22, "run outside device")?;
            }
            Ok(())
        });
    }
}
