//! Tokenization and feature hashing (the "hashing trick" vectorizer used
//! by the sentiment pipeline, mirroring what the NLTK benchmark does with
//! its bag-of-words features).

/// Lowercase word tokenizer: splits on non-alphanumeric, drops empties.
pub fn tokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in text.chars() {
        if c.is_alphanumeric() || c == '\'' {
            cur.extend(c.to_lowercase());
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// FNV-1a 64-bit token hash.
pub fn hash_token(token: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in token.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hashing vectorizer: token counts into `buckets` dimensions with a
/// sign hash (reduces collision bias), then L2 normalization.
#[derive(Clone, Debug)]
pub struct HashingVectorizer {
    pub buckets: usize,
}

impl HashingVectorizer {
    pub fn new(buckets: usize) -> Self {
        assert!(buckets > 0);
        HashingVectorizer { buckets }
    }

    /// Vectorize into a fresh dense vector.
    pub fn vectorize(&self, text: &str) -> Vec<f32> {
        let mut v = vec![0.0f32; self.buckets];
        self.vectorize_into(text, &mut v);
        v
    }

    /// Vectorize into a caller-provided buffer (hot path: no allocation).
    pub fn vectorize_into(&self, text: &str, out: &mut [f32]) {
        assert_eq!(out.len(), self.buckets);
        out.fill(0.0);
        let mut any = false;
        for tok in tokenize(text) {
            let h = hash_token(&tok);
            let idx = (h % self.buckets as u64) as usize;
            let sign = if (h >> 63) == 0 { 1.0 } else { -1.0 };
            out[idx] += sign;
            any = true;
        }
        if any {
            let norm = out.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 0.0 {
                out.iter_mut().for_each(|x| *x /= norm);
            }
        }
    }
}

/// L2-normalize a vector in place; no-op on zero vectors.
pub fn l2_normalize(v: &mut [f32]) {
    let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 1e-12 {
        v.iter_mut().for_each(|x| *x /= norm);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, forall};

    #[test]
    fn tokenize_basics() {
        assert_eq!(tokenize("Hello, World!"), vec!["hello", "world"]);
        assert_eq!(tokenize("it's a-b c"), vec!["it's", "a", "b", "c"]);
        assert!(tokenize("  ...  ").is_empty());
        assert_eq!(tokenize("héllo wörld"), vec!["héllo", "wörld"]);
    }

    #[test]
    fn hash_is_stable_and_spread() {
        assert_eq!(hash_token("movie"), hash_token("movie"));
        assert_ne!(hash_token("movie"), hash_token("movies"));
    }

    #[test]
    fn vectorize_normalized_and_deterministic() {
        let v = HashingVectorizer::new(64);
        let a = v.vectorize("great fantastic wonderful movie");
        let b = v.vectorize("great fantastic wonderful movie");
        assert_eq!(a, b);
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn empty_text_is_zero_vector() {
        let v = HashingVectorizer::new(16);
        assert_eq!(v.vectorize("!!!"), vec![0.0; 16]);
    }

    #[test]
    fn different_texts_differ() {
        let v = HashingVectorizer::new(4096);
        let a = v.vectorize("i loved this movie");
        let b = v.vectorize("i hated this movie");
        assert_ne!(a, b);
    }

    #[test]
    fn property_vectorizer_norm_and_reuse() {
        forall("hashing vectorizer invariants", 100, |g| {
            let v = HashingVectorizer::new(g.usize(1..=512));
            let text = g.ascii_string(120);
            let dense = v.vectorize(&text);
            let mut reused = vec![7.0f32; v.buckets]; // dirty buffer
            v.vectorize_into(&text, &mut reused);
            check(dense == reused, "into == fresh")?;
            let norm: f32 = dense.iter().map(|x| x * x).sum::<f32>().sqrt();
            check(
                norm == 0.0 || (norm - 1.0).abs() < 1e-4,
                format!("norm {norm}"),
            )
        });
    }
}
