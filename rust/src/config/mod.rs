//! Typed experiment configuration loaded from TOML files (see
//! `configs/*.toml`). Everything has a paper-faithful default; a config
//! file overrides only what it names.
//!
//! ```toml
//! seed = 42
//! scale = 0.25
//!
//! [sched]
//! csd_batch = 40000
//! batch_ratio = 26
//! wakeup_s = 0.2
//! drives = 36
//! isp_drives = 36
//! dispatch = "polling"   # or "event" — see sched::DispatchMode (A4)
//!
//! [power]
//! server_idle_w = 167.0
//! csd_idle_w = 6.6
//!
//! [fleet]
//! servers = 4
//! shape = "mixed"          # all-csd | all-ssd | mixed
//! rack_bandwidth = 1.25e9  # top-of-rack link, bytes/s
//! rack_msg_overhead_s = 50e-6
//! weights = [36, 12, 36, 12]  # heterogeneous capacity weights (one per server)
//!
//! [traffic]
//! process = "poisson"      # poisson | bursty | closed — see traffic::ArrivalProcess
//! load = 0.7               # offered load, fraction of fleet nominal capacity
//! rate_rps = 5000.0        # absolute offered rate (overrides load)
//! requests = 20000
//! min_batch = 1            # batch formation: dispatch at this size ...
//! batch_timeout_s = 0.05   # ... or when the oldest request waited this long
//! clients = 64             # closed loop: concurrent clients
//! think_s = 1.0            # closed loop: mean think time
//! burstiness = 4.0         # bursty: peak/mean rate ratio
//! burst_on_s = 1.0         # bursty: mean ON-window length
//! policy = "jsq"           # rr | weighted | jsq | least-work — front-door balancer
//! slo_p99_s = 2.5          # p99 SLO (default: 4x the CSD batch service time)
//! admission = true         # SLO-aware admission control (shed past-deadline requests)
//! skew = 1.0               # hot-shard placement skew (Zipf-like; 0 = uniform)
//! retries = 3              # per-request retry budget (0 = no timeout/retry layer)
//! retry_timeout_s = 1.0    # first-attempt timeout (default: deadline-aware estimate)
//! hedge = true             # duplicate stragglers, first response wins
//! ingest_rate = 2000.0     # background update writes/s per server (0 = read-only)
//!
//! [flash]                  # per-drive flash geometry + management (ISSUE-8)
//! zns = false              # ZCSD-style zoned namespaces (host resets, no device GC)
//! background_gc = true     # opportunistic GC on idle dies ahead of the low-water mark
//! channels = 16            # geometry overrides (defaults: the 12-TB prototype);
//! dies_per_channel = 8     # fig13 shrinks these so GC fires within a serving run
//! blocks_per_die = 2500
//! pages_per_block = 2304
//! page_bytes = 16384
//!
//! [faults]                 # deterministic fault injection — see crate::faults
//! seed = 7                 # fault RNG stream (independent of the traffic seed)
//! ack_loss = 0.05          # P(CSD batch ack lost)
//! stall = 0.1              # P(CSD batch ack stalls stall_s)
//! stall_s = 1.0
//! drive_crash = 0.01       # P(ISP dies at a batch ack, permanent)
//! server_crash_at = 0.3    # crash crash_server at this fraction of the arrival window
//! crash_server = 0
//! rejoin_s = 5.0           # omit for a permanent crash
//! link_drop = 0.02         # P(rack response message dropped)
//! link_dup = 0.02          # P(rack response message duplicated)
//!
//! [trace]                  # deterministic request tracing — see crate::trace
//! enabled = true           # arm the span tracer (off = the exact untraced path)
//! cap = 10000              # keep only the last N request timelines (0 = unbounded)
//! sample = 8               # trace every Nth request by id (1 = all)
//! format = "jsonl"         # jsonl | chrome — export format for `out`
//! out = "trace.jsonl"      # export path (omit to report in memory only)
//!
//! [autoscale]              # elastic fleet (ISSUE-10) — see traffic::elastic
//! policy = "predictive"    # reactive | predictive — resize-decision policy
//! min_servers = 1          # fleet floor (never drains below)
//! max_servers = 8          # fleet ceiling ([fleet] servers is the initial size)
//! check_interval_s = 1.0   # seconds between autoscaler evaluations
//! hysteresis = 0.25        # scale-down dead band in (0,1)
//! estimator_window_s = 10.0  # predictive arrival-rate estimator memory
//! target_util = 0.8        # per-server utilization the fleet is sized for, (0,1]
//! rebalance = true         # migrate hot shards between servers mid-run
//! rebalance_threshold = 0.55 # routed-share trigger for a migration, (0,1]
//! shards = 32              # routable shards (>= max_servers)
//! ```
//!
//! `[fleet] replicas = 1` enables shard failover routing (ISSUE-6).

use std::path::Path;

use crate::cluster::fleet::{FleetConfig, FleetShape};
use crate::codec::toml::TomlTable;
use crate::power::PowerModel;
use crate::sched::{DispatchMode, SchedConfig};
use crate::trace::{TraceConfig, TraceFormat};
use crate::traffic::{parse_policy, parse_process, TrafficConfig};
use crate::workloads::App;

/// A full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub seed: u64,
    /// Dataset scale factor vs the paper (1.0 = full size).
    pub scale: f64,
    pub app: Option<App>,
    pub sched: SchedConfig,
    pub power: PowerModel,
    /// Fleet-level settings (`[fleet]`). Its `sched` template is kept in
    /// sync with [`ExperimentConfig::sched`], so `solana fleet` sees the
    /// same per-server scheduler the single-server commands use.
    pub fleet: FleetConfig,
    /// Serving-traffic settings (`[traffic]`), consumed by
    /// `solana serve` and the Fig 9 experiment.
    pub traffic: TrafficConfig,
    /// Request-tracing settings (`[trace]`, ISSUE-9), consumed by
    /// `solana serve --trace`. Disabled by default — the exact
    /// untraced serving path.
    pub trace: TraceConfig,
    /// Whether the file explicitly set sched.csd_batch / batch_ratio /
    /// traffic.requests (CLI precedence: flag > file > per-app default).
    pub batch_explicit: bool,
    pub ratio_explicit: bool,
    pub requests_explicit: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 42,
            scale: 0.25,
            app: None,
            sched: SchedConfig::default(),
            power: PowerModel::default(),
            fleet: FleetConfig::default(),
            traffic: TrafficConfig::default(),
            trace: TraceConfig::default(),
            batch_explicit: false,
            ratio_explicit: false,
            requests_explicit: false,
        }
    }
}

impl ExperimentConfig {
    pub fn from_file(path: impl AsRef<Path>) -> anyhow::Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> anyhow::Result<ExperimentConfig> {
        let t = TomlTable::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = t.u64("seed") {
            cfg.seed = v;
            cfg.sched.seed = v;
            cfg.traffic.seed = v;
        }
        if let Some(v) = t.f64("scale") {
            anyhow::ensure!(v > 0.0 && v <= 1.0, "scale must be in (0, 1]");
            cfg.scale = v;
        }
        if let Some(name) = t.str("app") {
            cfg.app = Some(parse_app(name)?);
        }
        if let Some(v) = t.u64("sched.csd_batch") {
            anyhow::ensure!(v > 0, "sched.csd_batch must be positive");
            cfg.sched.csd_batch = v;
            cfg.batch_explicit = true;
        }
        if let Some(v) = t.f64("sched.batch_ratio") {
            anyhow::ensure!(v >= 1.0, "sched.batch_ratio must be >= 1");
            cfg.sched.batch_ratio = v;
            cfg.ratio_explicit = true;
        }
        if let Some(v) = t.f64("sched.wakeup_s") {
            anyhow::ensure!(v > 0.0, "sched.wakeup_s must be positive");
            cfg.sched.wakeup_secs = v;
        }
        if let Some(v) = t.u64("sched.drives") {
            cfg.sched.drives = v as usize;
        }
        if let Some(v) = t.u64("sched.isp_drives") {
            cfg.sched.isp_drives = v as usize;
        }
        if let Some(v) = t.bool("sched.use_host") {
            cfg.sched.use_host = v;
        }
        if let Some(v) = t.bool("sched.coalesce_wakes") {
            cfg.sched.coalesce_wakes = v;
        }
        if let Some(v) = t.str("sched.dispatch") {
            cfg.sched.dispatch = parse_dispatch(v)?;
        }
        if let Some(v) = t.f64("power.server_idle_w") {
            cfg.power.server_idle_w = v;
        }
        if let Some(v) = t.f64("power.csd_idle_w") {
            cfg.power.csd_idle_w = v;
        }
        if let Some(v) = t.f64("power.host_active_w") {
            cfg.power.host_active_w = v;
        }
        if let Some(v) = t.f64("power.isp_active_w") {
            cfg.power.isp_active_w = v;
        }
        if let Some(v) = t.u64("fleet.servers") {
            anyhow::ensure!(v >= 1, "fleet.servers must be >= 1");
            cfg.fleet.servers = v as usize;
        }
        if let Some(v) = t.str("fleet.shape") {
            cfg.fleet.shape = parse_shape(v)?;
        }
        if let Some(v) = t.f64("fleet.rack_bandwidth") {
            // is_finite too (ISSUE-6 satellite): `inf` parses as a
            // float and would silently zero every rack transfer time.
            anyhow::ensure!(
                v > 0.0 && v.is_finite(),
                "fleet.rack_bandwidth must be positive and finite"
            );
            cfg.fleet.rack_bandwidth = v;
        }
        if let Some(v) = t.u64("fleet.replicas") {
            // The replicas < servers invariant is enforced by
            // serve_fleet, where the final server count is known.
            cfg.fleet.replicas = v as usize;
        }
        if let Some(v) = t.f64("fleet.rack_msg_overhead_s") {
            anyhow::ensure!(v >= 0.0, "fleet.rack_msg_overhead_s must be non-negative");
            cfg.fleet.rack_msg_overhead = v;
        }
        if let Some(v) = t.get("fleet.weights") {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("fleet.weights must be an array of integers"))?;
            anyhow::ensure!(
                !arr.is_empty(),
                "fleet.weights must not be empty: list one positive weight per server (or omit \
                 the key for homogeneous capacity)"
            );
            let mut weights = Vec::with_capacity(arr.len());
            for x in arr {
                let w = x
                    .as_i64()
                    .filter(|&w| w > 0)
                    .ok_or_else(|| anyhow::anyhow!("fleet.weights entries must be positive integers"))?;
                weights.push(w as u64);
            }
            cfg.fleet.weights = Some(weights);
        }
        if let Some(v) = t.str("traffic.process") {
            cfg.traffic.process = parse_process(v)?;
        }
        if let Some(v) = t.f64("traffic.load") {
            anyhow::ensure!(v > 0.0 && v.is_finite(), "traffic.load must be positive");
            cfg.traffic.load = v;
        }
        if let Some(v) = t.f64("traffic.rate_rps") {
            anyhow::ensure!(v > 0.0 && v.is_finite(), "traffic.rate_rps must be positive");
            cfg.traffic.rate_rps = Some(v);
        }
        if let Some(v) = t.u64("traffic.requests") {
            anyhow::ensure!(v >= 1, "traffic.requests must be >= 1");
            cfg.traffic.requests = v;
            cfg.requests_explicit = true;
        }
        if let Some(v) = t.u64("traffic.min_batch") {
            anyhow::ensure!(v >= 1, "traffic.min_batch must be >= 1");
            cfg.traffic.min_batch = v;
        }
        if let Some(v) = t.f64("traffic.batch_timeout_s") {
            anyhow::ensure!(v >= 0.0 && v.is_finite(), "traffic.batch_timeout_s must be non-negative");
            cfg.traffic.batch_timeout_s = v;
        }
        if let Some(v) = t.u64("traffic.clients") {
            anyhow::ensure!(v >= 1, "traffic.clients must be >= 1");
            cfg.traffic.clients = v as usize;
        }
        if let Some(v) = t.f64("traffic.think_s") {
            anyhow::ensure!(v > 0.0 && v.is_finite(), "traffic.think_s must be positive");
            cfg.traffic.think_s = v;
        }
        if let Some(v) = t.f64("traffic.burstiness") {
            anyhow::ensure!(v >= 1.0 && v.is_finite(), "traffic.burstiness must be >= 1");
            cfg.traffic.burstiness = v;
        }
        if let Some(v) = t.f64("traffic.burst_on_s") {
            anyhow::ensure!(v > 0.0 && v.is_finite(), "traffic.burst_on_s must be positive");
            cfg.traffic.burst_on_s = v;
        }
        if let Some(v) = t.str("traffic.policy") {
            cfg.traffic.policy = parse_policy(v)?;
        }
        if let Some(v) = t.f64("traffic.slo_p99_s") {
            anyhow::ensure!(v > 0.0 && v.is_finite(), "traffic.slo_p99_s must be positive");
            cfg.traffic.slo_p99_s = Some(v);
        }
        if let Some(v) = t.get("traffic.admission") {
            // Strict: a non-boolean here must not silently disable the
            // admission gate the config asked for.
            cfg.traffic.admission = v.as_bool().ok_or_else(|| {
                anyhow::anyhow!("traffic.admission must be a boolean (true|false)")
            })?;
        }
        if let Some(v) = t.get("traffic.skew") {
            // Strict like `admission`: a non-numeric value must not
            // silently run an unskewed experiment.
            let skew = v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("traffic.skew must be a non-negative number")
            })?;
            anyhow::ensure!(
                skew >= 0.0 && skew.is_finite(),
                "traffic.skew must be non-negative and finite"
            );
            cfg.traffic.skew = skew;
        }
        if let Some(v) = t.u64("traffic.retries") {
            cfg.traffic.retries = v as u32;
        }
        if let Some(v) = t.f64("traffic.retry_timeout_s") {
            anyhow::ensure!(
                v > 0.0 && v.is_finite(),
                "traffic.retry_timeout_s must be positive and finite"
            );
            cfg.traffic.retry_timeout_s = Some(v);
        }
        if let Some(v) = t.get("traffic.hedge") {
            // Strict like `admission`: a non-boolean must not silently
            // disable the hedging the config asked for.
            cfg.traffic.hedge = v
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("traffic.hedge must be a boolean (true|false)"))?;
        }
        if let Some(v) = t.f64("traffic.ingest_rate") {
            anyhow::ensure!(
                v >= 0.0 && v.is_finite(),
                "traffic.ingest_rate must be non-negative and finite"
            );
            cfg.traffic.ingest_rate = v;
        }
        // ---- [flash]: per-drive geometry + management (ISSUE-8) -----
        {
            let fl = &mut cfg.sched.csd.flash;
            if let Some(v) = t.get("flash.zns") {
                fl.zns = v
                    .as_bool()
                    .ok_or_else(|| anyhow::anyhow!("flash.zns must be a boolean (true|false)"))?;
            }
            if let Some(v) = t.get("flash.background_gc") {
                fl.background_gc = v.as_bool().ok_or_else(|| {
                    anyhow::anyhow!("flash.background_gc must be a boolean (true|false)")
                })?;
            }
            if let Some(v) = t.u64("flash.channels") {
                anyhow::ensure!((1..=u16::MAX as u64).contains(&v), "flash.channels out of range");
                fl.channels = v as u16;
            }
            if let Some(v) = t.u64("flash.dies_per_channel") {
                anyhow::ensure!(
                    (1..=u16::MAX as u64).contains(&v),
                    "flash.dies_per_channel out of range"
                );
                fl.dies_per_channel = v as u16;
            }
            if let Some(v) = t.u64("flash.blocks_per_die") {
                // ≥ 2: one open block plus at least one headroom block.
                anyhow::ensure!(
                    (2..=u32::MAX as u64).contains(&v),
                    "flash.blocks_per_die must be >= 2"
                );
                fl.blocks_per_die = v as u32;
            }
            if let Some(v) = t.u64("flash.pages_per_block") {
                anyhow::ensure!(
                    (1..=u32::MAX as u64).contains(&v),
                    "flash.pages_per_block out of range"
                );
                fl.pages_per_block = v as u32;
            }
            if let Some(v) = t.u64("flash.page_bytes") {
                anyhow::ensure!(v >= 512, "flash.page_bytes must be >= 512");
                fl.page_bytes = v;
            }
            anyhow::ensure!(
                !(fl.zns && fl.background_gc),
                "flash.zns and flash.background_gc are mutually exclusive: a zoned drive \
                 has no device-side GC to run in the background"
            );
        }
        // ---- [faults]: deterministic fault injection (ISSUE-6) ------
        {
            use crate::faults::FaultsConfig;
            let mut fc = FaultsConfig::default();
            let mut present = false;
            if let Some(v) = t.u64("faults.seed") {
                fc.seed = v;
                present = true;
            }
            if let Some(v) = t.f64("faults.ack_loss") {
                fc.ack_loss = v;
                present = true;
            }
            if let Some(v) = t.f64("faults.stall") {
                fc.stall = v;
                present = true;
            }
            if let Some(v) = t.f64("faults.stall_s") {
                fc.stall_s = v;
                present = true;
            }
            if let Some(v) = t.f64("faults.drive_crash") {
                fc.drive_crash = v;
                present = true;
            }
            if let Some(v) = t.f64("faults.server_crash_at") {
                fc.server_crash_at = Some(v);
                present = true;
            }
            if let Some(v) = t.u64("faults.crash_server") {
                fc.crash_server = v as usize;
                present = true;
            }
            if let Some(v) = t.f64("faults.rejoin_s") {
                fc.rejoin_s = Some(v);
                present = true;
            }
            if let Some(v) = t.f64("faults.link_drop") {
                fc.link_drop = v;
                present = true;
            }
            if let Some(v) = t.f64("faults.link_dup") {
                fc.link_dup = v;
                present = true;
            }
            if present {
                // Probability ranges etc. are checkable now; the
                // crash_server-vs-servers bound is re-checked by
                // serve_fleet against the final fleet size.
                fc.validate(cfg.fleet.servers.max(fc.crash_server + 1))?;
                cfg.traffic.faults = Some(fc);
            }
        }
        // ---- [trace]: deterministic request tracing (ISSUE-9) -------
        {
            if let Some(v) = t.get("trace.enabled") {
                // Strict like `admission`: a non-boolean must not
                // silently run untraced when the config asked for spans.
                cfg.trace.enabled = v.as_bool().ok_or_else(|| {
                    anyhow::anyhow!("trace.enabled must be a boolean (true|false)")
                })?;
            }
            if let Some(v) = t.u64("trace.cap") {
                cfg.trace.ring_cap = v as usize;
            }
            if let Some(v) = t.u64("trace.sample") {
                cfg.trace.sample_every = v;
            }
            if let Some(v) = t.str("trace.format") {
                cfg.trace.format = TraceFormat::parse(v).ok_or_else(|| {
                    anyhow::anyhow!("unknown trace format '{v}' (expected jsonl|chrome)")
                })?;
            }
            if let Some(v) = t.str("trace.out") {
                cfg.trace.out = Some(v.to_string());
            }
            cfg.trace.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        // ---- [autoscale]: elastic fleet (ISSUE-10) ------------------
        {
            use crate::traffic::{parse_autoscale_policy, AutoscaleConfig};
            let mut ac = AutoscaleConfig::default();
            let mut present = false;
            if let Some(v) = t.str("autoscale.policy") {
                ac.policy = parse_autoscale_policy(v)?;
                present = true;
            }
            if let Some(v) = t.u64("autoscale.min_servers") {
                ac.min_servers = v as usize;
                present = true;
            }
            if let Some(v) = t.u64("autoscale.max_servers") {
                ac.max_servers = v as usize;
                present = true;
            }
            if let Some(v) = t.f64("autoscale.check_interval_s") {
                ac.check_interval_s = v;
                present = true;
            }
            if let Some(v) = t.f64("autoscale.hysteresis") {
                ac.hysteresis = v;
                present = true;
            }
            if let Some(v) = t.f64("autoscale.estimator_window_s") {
                ac.estimator_window_s = v;
                present = true;
            }
            if let Some(v) = t.f64("autoscale.target_util") {
                ac.target_util = v;
                present = true;
            }
            if let Some(v) = t.get("autoscale.rebalance") {
                // Strict like `trace.enabled`: a non-boolean must not
                // silently leave the rebalancer armed (its default).
                ac.rebalance = v.as_bool().ok_or_else(|| {
                    anyhow::anyhow!("autoscale.rebalance must be a boolean (true|false)")
                })?;
                present = true;
            }
            if let Some(v) = t.f64("autoscale.rebalance_threshold") {
                ac.rebalance_threshold = v;
                present = true;
            }
            if let Some(v) = t.u64("autoscale.shards") {
                ac.shards = v as usize;
                present = true;
            }
            if present {
                // Every knob range is checkable now, against the
                // `[fleet]` section; serve_fleet re-validates against
                // the final (CLI-layered) fleet.
                ac.validate(&cfg.fleet)?;
                cfg.traffic.autoscale = Some(ac);
            }
        }
        anyhow::ensure!(
            cfg.sched.isp_drives <= cfg.sched.drives,
            "isp_drives ({}) exceeds drives ({})",
            cfg.sched.isp_drives,
            cfg.sched.drives
        );
        // The fleet's per-server template is the `[sched]` section.
        cfg.fleet.sched = cfg.sched.clone();
        Ok(cfg)
    }
}

/// Parse an app name from config/CLI.
pub fn parse_app(name: &str) -> anyhow::Result<App> {
    match name {
        "speech" | "speech_to_text" | "stt" => Ok(App::SpeechToText),
        "recommender" | "rec" | "movies" => Ok(App::Recommender),
        "sentiment" | "tweets" => Ok(App::Sentiment),
        other => anyhow::bail!(
            "unknown app '{other}' (expected speech|recommender|sentiment)"
        ),
    }
}

/// Parse a fleet shape from config/CLI (see [`FleetShape`]).
pub fn parse_shape(name: &str) -> anyhow::Result<FleetShape> {
    match name {
        "all-csd" | "all_csd" | "csd" => Ok(FleetShape::AllCsd),
        "all-ssd" | "all_ssd" | "ssd" | "baseline" => Ok(FleetShape::AllSsd),
        "mixed" | "hybrid" => Ok(FleetShape::Mixed),
        other => anyhow::bail!("unknown fleet shape '{other}' (expected all-csd|all-ssd|mixed)"),
    }
}

/// Parse a dispatch mode from config/CLI (see [`DispatchMode`]).
pub fn parse_dispatch(name: &str) -> anyhow::Result<DispatchMode> {
    match name {
        "polling" | "poll" => Ok(DispatchMode::Polling),
        "event" | "event-driven" | "event_driven" | "eventdriven" => Ok(DispatchMode::EventDriven),
        other => anyhow::bail!("unknown dispatch mode '{other}' (expected polling|event)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_without_file() {
        let c = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(c.sched.drives, 36);
        assert_eq!(c.power.server_idle_w, 167.0);
    }

    #[test]
    fn coalesce_wakes_override() {
        let c = ExperimentConfig::from_toml("[sched]\ncoalesce_wakes = false\n").unwrap();
        assert!(!c.sched.coalesce_wakes);
        assert!(ExperimentConfig::from_toml("").unwrap().sched.coalesce_wakes);
    }

    #[test]
    fn overrides_apply() {
        let c = ExperimentConfig::from_toml(
            "seed = 7\nscale = 0.5\napp = \"sentiment\"\n[sched]\ncsd_batch = 1000\ndrives = 12\nisp_drives = 12\n[power]\ncsd_idle_w = 7.0\n",
        )
        .unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.sched.seed, 7);
        assert_eq!(c.scale, 0.5);
        assert_eq!(c.app, Some(App::Sentiment));
        assert_eq!(c.sched.csd_batch, 1000);
        assert_eq!(c.sched.drives, 12);
        assert_eq!(c.power.csd_idle_w, 7.0);
    }

    #[test]
    fn validation_rejects_nonsense() {
        assert!(ExperimentConfig::from_toml("scale = 2.0").is_err());
        assert!(ExperimentConfig::from_toml("[sched]\ncsd_batch = 0").is_err());
        assert!(ExperimentConfig::from_toml("[sched]\ndrives = 4\nisp_drives = 8").is_err());
        assert!(ExperimentConfig::from_toml("app = \"nope\"").is_err());
    }

    #[test]
    fn dispatch_override() {
        let c = ExperimentConfig::from_toml("[sched]\ndispatch = \"event\"\n").unwrap();
        assert_eq!(c.sched.dispatch, DispatchMode::EventDriven);
        let d = ExperimentConfig::from_toml("[sched]\ndispatch = \"polling\"\n").unwrap();
        assert_eq!(d.sched.dispatch, DispatchMode::Polling);
        assert_eq!(
            ExperimentConfig::from_toml("").unwrap().sched.dispatch,
            DispatchMode::Polling,
            "polling stays the paper-faithful default"
        );
        assert!(ExperimentConfig::from_toml("[sched]\ndispatch = \"sometimes\"").is_err());
    }

    #[test]
    fn dispatch_aliases() {
        assert_eq!(parse_dispatch("poll").unwrap(), DispatchMode::Polling);
        assert_eq!(parse_dispatch("event-driven").unwrap(), DispatchMode::EventDriven);
        assert_eq!(parse_dispatch("event_driven").unwrap(), DispatchMode::EventDriven);
        assert!(parse_dispatch("grid").is_err());
    }

    #[test]
    fn fleet_section_parses_and_syncs_sched_template() {
        let c = ExperimentConfig::from_toml(
            "seed = 9\n[sched]\ncsd_batch = 123\n[fleet]\nservers = 4\nshape = \"mixed\"\nrack_bandwidth = 2.5e9\nrack_msg_overhead_s = 1e-4\n",
        )
        .unwrap();
        assert_eq!(c.fleet.servers, 4);
        assert_eq!(c.fleet.shape, FleetShape::Mixed);
        assert_eq!(c.fleet.rack_bandwidth, 2.5e9);
        assert_eq!(c.fleet.rack_msg_overhead, 1e-4);
        assert_eq!(c.fleet.sched.csd_batch, 123, "[sched] is the fleet template");
        assert_eq!(c.fleet.sched.seed, 9, "seed flows through the [sched] template");
        // defaults without a [fleet] section
        let d = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(d.fleet.servers, 1);
        assert_eq!(d.fleet.shape, FleetShape::AllCsd);
    }

    #[test]
    fn fleet_section_validation() {
        assert!(ExperimentConfig::from_toml("[fleet]\nservers = 0").is_err());
        assert!(ExperimentConfig::from_toml("[fleet]\nshape = \"pyramid\"").is_err());
        assert!(ExperimentConfig::from_toml("[fleet]\nrack_bandwidth = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[fleet]\nrack_msg_overhead_s = -0.1").is_err());
    }

    #[test]
    fn traffic_section_parses_and_validates() {
        use crate::traffic::{ArrivalProcess, LbPolicy};
        let c = ExperimentConfig::from_toml(
            "seed = 11\n[traffic]\nprocess = \"bursty\"\nload = 0.8\nrequests = 5000\nmin_batch = 32\nbatch_timeout_s = 0.02\nburstiness = 6.0\npolicy = \"weighted\"\nslo_p99_s = 1.5\n",
        )
        .unwrap();
        assert_eq!(c.traffic.process, ArrivalProcess::Bursty);
        assert_eq!(c.traffic.load, 0.8);
        assert_eq!(c.traffic.requests, 5000);
        assert_eq!(c.traffic.min_batch, 32);
        assert_eq!(c.traffic.batch_timeout_s, 0.02);
        assert_eq!(c.traffic.burstiness, 6.0);
        assert_eq!(c.traffic.policy, LbPolicy::WeightedCapacity);
        assert_eq!(c.traffic.slo_p99_s, Some(1.5));
        assert_eq!(c.traffic.seed, 11, "global seed flows into the traffic seed");
        // defaults without a [traffic] section
        let d = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(d.traffic.process, ArrivalProcess::Poisson);
        assert_eq!(d.traffic.min_batch, 1);
        assert_eq!(d.traffic.policy, LbPolicy::JoinShortestQueue);
        assert_eq!(d.traffic.slo_p99_s, None);
        // validation
        assert!(ExperimentConfig::from_toml("[traffic]\nload = 0.0").is_err());
        assert!(ExperimentConfig::from_toml("[traffic]\nprocess = \"psychic\"").is_err());
        assert!(ExperimentConfig::from_toml("[traffic]\nmin_batch = 0").is_err());
        assert!(ExperimentConfig::from_toml("[traffic]\npolicy = \"chaos\"").is_err());
        assert!(ExperimentConfig::from_toml("[traffic]\nburstiness = 0.5").is_err());
    }

    #[test]
    fn traffic_control_plane_section_parses_and_validates() {
        use crate::traffic::LbPolicy;
        // ISSUE-5: admission / skew / least-work through the TOML path.
        let c = ExperimentConfig::from_toml(
            "[traffic]\nadmission = true\nskew = 1.5\npolicy = \"least-work\"\n",
        )
        .unwrap();
        assert!(c.traffic.admission);
        assert_eq!(c.traffic.skew, 1.5);
        assert_eq!(c.traffic.policy, LbPolicy::LeastWork);
        // defaults: the PR-4 behavior
        let d = ExperimentConfig::from_toml("").unwrap();
        assert!(!d.traffic.admission);
        assert_eq!(d.traffic.skew, 0.0);
        // aliases and rejects
        assert_eq!(
            ExperimentConfig::from_toml("[traffic]\npolicy = \"lw\"\n")
                .unwrap()
                .traffic
                .policy,
            LbPolicy::LeastWork
        );
        assert!(ExperimentConfig::from_toml("[traffic]\nskew = -0.1").is_err());
        assert!(ExperimentConfig::from_toml("[traffic]\nskew = \"1.5\"").is_err());
        assert!(ExperimentConfig::from_toml("[traffic]\nadmission = \"sometimes\"").is_err());
        // empty weight vectors are rejected at parse time with a clear
        // message, not deferred to a later length check
        let err = ExperimentConfig::from_toml("[fleet]\nservers = 2\nweights = []\n").unwrap_err();
        assert!(err.to_string().contains("empty"), "unhelpful error: {err}");
    }

    #[test]
    fn fleet_weights_parse_and_validate() {
        let c = ExperimentConfig::from_toml("[fleet]\nservers = 3\nweights = [36, 12, 24]\n")
            .unwrap();
        assert_eq!(c.fleet.weights, Some(vec![36, 12, 24]));
        assert!(c.fleet.validate_weights().is_ok());
        // no weights key → homogeneous default
        assert_eq!(ExperimentConfig::from_toml("").unwrap().fleet.weights, None);
        // bad entries rejected at parse time
        assert!(ExperimentConfig::from_toml("[fleet]\nweights = [36, 0]").is_err());
        assert!(ExperimentConfig::from_toml("[fleet]\nweights = [36, -2]").is_err());
        assert!(ExperimentConfig::from_toml("[fleet]\nweights = \"36\"").is_err());
        // length mismatch surfaces via validate_weights (servers known later)
        let mismatch = ExperimentConfig::from_toml("[fleet]\nservers = 2\nweights = [1, 2, 3]\n")
            .unwrap();
        assert!(mismatch.fleet.validate_weights().is_err());
    }

    #[test]
    fn faults_section_parses_and_validates() {
        // ISSUE-6: the [faults] section and the resilience knobs.
        let c = ExperimentConfig::from_toml(
            "[fleet]\nservers = 4\nreplicas = 1\n\
             [traffic]\nretries = 3\nretry_timeout_s = 1.5\nhedge = true\n\
             [faults]\nseed = 99\nack_loss = 0.05\nstall = 0.1\nstall_s = 0.5\n\
             server_crash_at = 0.3\ncrash_server = 2\nrejoin_s = 4.0\nlink_drop = 0.02\n",
        )
        .unwrap();
        assert_eq!(c.fleet.replicas, 1);
        assert_eq!(c.traffic.retries, 3);
        assert_eq!(c.traffic.retry_timeout_s, Some(1.5));
        assert!(c.traffic.hedge);
        let fc = c.traffic.faults.expect("[faults] section present");
        assert_eq!(fc.seed, 99);
        assert_eq!(fc.ack_loss, 0.05);
        assert_eq!(fc.stall, 0.1);
        assert_eq!(fc.stall_s, 0.5);
        assert_eq!(fc.server_crash_at, Some(0.3));
        assert_eq!(fc.crash_server, 2);
        assert_eq!(fc.rejoin_s, Some(4.0));
        assert_eq!(fc.link_drop, 0.02);
        // no [faults] section → no fault plan at all
        let d = ExperimentConfig::from_toml("").unwrap();
        assert!(d.traffic.faults.is_none());
        assert_eq!(d.traffic.retries, 0);
        assert_eq!(d.traffic.retry_timeout_s, None);
        assert!(!d.traffic.hedge);
        assert_eq!(d.fleet.replicas, 0);
        // validation at parse time
        assert!(ExperimentConfig::from_toml("[faults]\nack_loss = 1.5").is_err());
        assert!(ExperimentConfig::from_toml("[faults]\nstall_s = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[faults]\nserver_crash_at = 2.0").is_err());
        assert!(ExperimentConfig::from_toml("[traffic]\nretry_timeout_s = 0.0").is_err());
        assert!(ExperimentConfig::from_toml("[traffic]\nhedge = \"maybe\"").is_err());
        // the finite-bandwidth regression (ISSUE-6 satellite)
        assert!(ExperimentConfig::from_toml("[fleet]\nrack_bandwidth = inf").is_err());
    }

    #[test]
    fn flash_section_and_ingest_rate_parse_and_validate() {
        // ISSUE-8: the [flash] section and the ingest stream knob.
        let c = ExperimentConfig::from_toml(
            "[traffic]\ningest_rate = 2500.0\n\
             [flash]\nbackground_gc = true\nchannels = 2\ndies_per_channel = 2\n\
             blocks_per_die = 64\npages_per_block = 32\npage_bytes = 4096\n",
        )
        .unwrap();
        assert_eq!(c.traffic.ingest_rate, 2500.0);
        let fl = &c.sched.csd.flash;
        assert!(fl.background_gc);
        assert!(!fl.zns);
        assert_eq!(fl.channels, 2);
        assert_eq!(fl.dies_per_channel, 2);
        assert_eq!(fl.blocks_per_die, 64);
        assert_eq!(fl.pages_per_block, 32);
        assert_eq!(fl.page_bytes, 4096);
        // the [sched] template (and so the fleet) carries the geometry
        assert_eq!(c.fleet.sched.csd.flash.blocks_per_die, 64);
        // zns parses too
        let z = ExperimentConfig::from_toml("[flash]\nzns = true\n").unwrap();
        assert!(z.sched.csd.flash.zns);
        // defaults: the 12-TB prototype geometry, everything off
        let d = ExperimentConfig::from_toml("").unwrap();
        assert!(!d.sched.csd.flash.zns);
        assert!(!d.sched.csd.flash.background_gc);
        assert_eq!(d.sched.csd.flash.channels, 16);
        assert_eq!(d.traffic.ingest_rate, 0.0);
        // rejects
        assert!(ExperimentConfig::from_toml("[traffic]\ningest_rate = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[flash]\nzns = \"maybe\"").is_err());
        assert!(ExperimentConfig::from_toml("[flash]\nbackground_gc = 1").is_err());
        assert!(ExperimentConfig::from_toml("[flash]\nblocks_per_die = 1").is_err());
        assert!(ExperimentConfig::from_toml("[flash]\npage_bytes = 100").is_err());
        assert!(ExperimentConfig::from_toml("[flash]\nchannels = 0").is_err());
        assert!(
            ExperimentConfig::from_toml("[flash]\nzns = true\nbackground_gc = true").is_err(),
            "zoned drives have no device GC to background"
        );
    }

    #[test]
    fn trace_section_parses_and_validates() {
        // ISSUE-9: the [trace] section.
        let c = ExperimentConfig::from_toml(
            "[trace]\nenabled = true\ncap = 500\nsample = 8\nformat = \"chrome\"\nout = \"t.json\"\n",
        )
        .unwrap();
        assert!(c.trace.enabled);
        assert_eq!(c.trace.ring_cap, 500);
        assert_eq!(c.trace.sample_every, 8);
        assert_eq!(c.trace.format, TraceFormat::Chrome);
        assert_eq!(c.trace.out.as_deref(), Some("t.json"));
        assert!(c.trace.tracer().is_on());
        // defaults: tracing off, the exact untraced path
        let d = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(d.trace, TraceConfig::default());
        assert!(!d.trace.tracer().is_on());
        // rejects
        assert!(ExperimentConfig::from_toml("[trace]\nenabled = \"maybe\"").is_err());
        assert!(ExperimentConfig::from_toml("[trace]\nformat = \"svg\"").is_err());
        assert!(ExperimentConfig::from_toml("[trace]\nsample = 0").is_err());
    }

    #[test]
    fn autoscale_section_parses_and_validates() {
        use crate::traffic::AutoscalePolicy;
        // ISSUE-10: the [autoscale] section.
        let c = ExperimentConfig::from_toml(
            "[fleet]\nservers = 2\n\
             [autoscale]\npolicy = \"reactive\"\nmin_servers = 2\nmax_servers = 6\n\
             check_interval_s = 0.5\nhysteresis = 0.3\nestimator_window_s = 5.0\n\
             target_util = 0.7\nrebalance = false\nrebalance_threshold = 0.6\nshards = 12\n",
        )
        .unwrap();
        let ac = c.traffic.autoscale.expect("[autoscale] section present");
        assert_eq!(ac.policy, AutoscalePolicy::Reactive);
        assert_eq!(ac.min_servers, 2);
        assert_eq!(ac.max_servers, 6);
        assert_eq!(ac.check_interval_s, 0.5);
        assert_eq!(ac.hysteresis, 0.3);
        assert_eq!(ac.estimator_window_s, 5.0);
        assert_eq!(ac.target_util, 0.7);
        assert!(!ac.rebalance);
        assert_eq!(ac.rebalance_threshold, 0.6);
        assert_eq!(ac.shards, 12);
        // any single key arms the section with defaults around it
        let one = ExperimentConfig::from_toml("[autoscale]\nmax_servers = 4\n").unwrap();
        let ac = one.traffic.autoscale.expect("single key arms the section");
        assert_eq!(ac.max_servers, 4);
        assert_eq!(ac.policy, AutoscalePolicy::Predictive, "default policy");
        // no [autoscale] section → the exact static serving path
        let d = ExperimentConfig::from_toml("").unwrap();
        assert!(d.traffic.autoscale.is_none());
        // validation at parse time: one rejection per knob
        assert!(ExperimentConfig::from_toml("[autoscale]\npolicy = \"psychic\"").is_err());
        assert!(ExperimentConfig::from_toml("[autoscale]\nmin_servers = 0").is_err());
        assert!(
            ExperimentConfig::from_toml("[autoscale]\nmin_servers = 5\nmax_servers = 2").is_err()
        );
        assert!(ExperimentConfig::from_toml("[autoscale]\ncheck_interval_s = 0.0").is_err());
        assert!(ExperimentConfig::from_toml("[autoscale]\ncheck_interval_s = inf").is_err());
        assert!(ExperimentConfig::from_toml("[autoscale]\nhysteresis = 1.5").is_err());
        assert!(ExperimentConfig::from_toml("[autoscale]\nhysteresis = nan").is_err());
        assert!(ExperimentConfig::from_toml("[autoscale]\nestimator_window_s = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[autoscale]\ntarget_util = 0.0").is_err());
        assert!(ExperimentConfig::from_toml("[autoscale]\ntarget_util = 1.5").is_err());
        assert!(ExperimentConfig::from_toml("[autoscale]\nrebalance = \"maybe\"").is_err());
        assert!(ExperimentConfig::from_toml("[autoscale]\nrebalance_threshold = 0.0").is_err());
        assert!(
            ExperimentConfig::from_toml("[autoscale]\nmax_servers = 8\nshards = 4").is_err(),
            "every active server needs at least one shard"
        );
        // cross-section checks against [fleet]
        assert!(
            ExperimentConfig::from_toml(
                "[fleet]\nservers = 4\nreplicas = 1\n[autoscale]\nmin_servers = 1\n"
            )
            .is_err(),
            "replicas must fit the smallest fleet"
        );
        assert!(
            ExperimentConfig::from_toml(
                "[fleet]\nservers = 2\nweights = [36, 12]\n[autoscale]\nmax_servers = 4\n"
            )
            .is_err(),
            "explicit weights assume fixed membership"
        );
    }

    #[test]
    fn shape_aliases() {
        assert_eq!(parse_shape("csd").unwrap(), FleetShape::AllCsd);
        assert_eq!(parse_shape("all_ssd").unwrap(), FleetShape::AllSsd);
        assert_eq!(parse_shape("baseline").unwrap(), FleetShape::AllSsd);
        assert_eq!(parse_shape("hybrid").unwrap(), FleetShape::Mixed);
        assert!(parse_shape("pyramid").is_err());
    }

    #[test]
    fn app_aliases() {
        assert_eq!(parse_app("stt").unwrap(), App::SpeechToText);
        assert_eq!(parse_app("movies").unwrap(), App::Recommender);
        assert_eq!(parse_app("tweets").unwrap(), App::Sentiment);
    }
}
