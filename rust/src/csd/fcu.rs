//! Flash controller unit: NVMe front-end + ECC-equipped back-end.
//!
//! §III-A1: the FE receives and validates host IO commands and hands them
//! to the BE; the BE schedules flash operations over the 16-channel bus,
//! runs ECC on every page read, and serves **both** the host path and the
//! ISP path ("the flash media controller is responsible for handling
//! requests from both the ISP engine and the host", §III-C2). The ISP
//! bypasses the FE entirely — the FE command overhead is charged by the
//! caller ([`super::Csd`]) only on the host path.

use super::flash::{FlashArray, FlashConfig};
use super::ftl::{Ftl, FtlStats};
use crate::sim::{Servers, SimTime};
use crate::util::div_ceil;

/// Who issued an IO — determines accounting (and FE involvement, which
/// the caller applies).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoRequester {
    Host,
    Isp,
}

/// Byte counters per requester, used for the paper's data-transfer
/// reduction claims (§IV-B1: "2.58 GB out of the 3.8 GB never left the
/// storage unit").
#[derive(Clone, Copy, Debug, Default)]
pub struct IoCounters {
    pub host_read_bytes: u64,
    pub host_write_bytes: u64,
    pub isp_read_bytes: u64,
    pub isp_write_bytes: u64,
    pub host_cmds: u64,
    pub isp_cmds: u64,
}

/// The FCU: owns the flash array, the FTL, and the ECC pipeline.
pub struct Fcu {
    pub flash: FlashArray,
    pub ftl: Ftl,
    /// ECC decode engines (pipelined; 2 hardware units).
    ecc: Servers,
    ecc_per_page: f64,
    page_bytes: u64,
    pub io: IoCounters,
}

impl Fcu {
    pub fn new(cfg: &super::CsdConfig) -> Fcu {
        Fcu {
            flash: FlashArray::new(cfg.flash.clone()),
            ftl: Ftl::new(cfg.flash.clone()),
            ecc: Servers::new(2),
            ecc_per_page: cfg.ecc_per_page,
            page_bytes: cfg.flash.page_bytes,
            io: IoCounters::default(),
        }
    }

    pub fn flash_config(&self) -> &FlashConfig {
        &self.flash.cfg
    }

    /// Round a byte count up to whole flash pages.
    pub fn page_aligned(&self, bytes: u64) -> u64 {
        div_ceil(bytes.max(1), self.page_bytes) * self.page_bytes
    }

    fn lpn_range(&self, lba_byte: u64, bytes: u64) -> std::ops::Range<u64> {
        let first = lba_byte / self.page_bytes;
        let last = (lba_byte + bytes.max(1) - 1) / self.page_bytes;
        first..last + 1
    }

    /// Read an extent: per-page flash read + pipelined ECC decode.
    /// Returns when the last page has cleared ECC into shared DRAM.
    pub fn read(&mut self, now: SimTime, lba_byte: u64, bytes: u64, req: IoRequester) -> SimTime {
        let mut done = now;
        for lpn in self.lpn_range(lba_byte, bytes) {
            // Unmapped pages are zero-filled by the FE without touching
            // flash *or* ECC — there is no codeword to decode.
            if self.ftl.lookup(lpn).is_none() {
                continue;
            }
            let page_in = self.ftl.read_page(now, &mut self.flash, lpn);
            // ECC is a pipeline stage after the channel transfer.
            let ecc_done = self.ecc.acquire(page_in, self.ecc_per_page);
            done = done.max(ecc_done);
        }
        match req {
            IoRequester::Host => {
                self.io.host_read_bytes += bytes;
                self.io.host_cmds += 1;
            }
            IoRequester::Isp => {
                self.io.isp_read_bytes += bytes;
                self.io.isp_cmds += 1;
            }
        }
        done
    }

    /// Write an extent through the FTL; returns last program completion.
    pub fn write(&mut self, now: SimTime, lba_byte: u64, bytes: u64, req: IoRequester) -> SimTime {
        let mut done = now;
        for lpn in self.lpn_range(lba_byte, bytes) {
            done = done.max(self.ftl.write_page(now, &mut self.flash, lpn));
        }
        // Opportunistic background GC: idle dies relocate ahead of the
        // low-water mark, stealing die/channel bandwidth from future IO
        // instead of stalling this write.
        if self.flash.cfg.background_gc {
            self.ftl.background_collect(now, &mut self.flash);
        }
        match req {
            IoRequester::Host => {
                self.io.host_write_bytes += bytes;
                self.io.host_cmds += 1;
            }
            IoRequester::Isp => {
                self.io.isp_write_bytes += bytes;
                self.io.isp_cmds += 1;
            }
        }
        done
    }

    pub fn ftl_stats(&self) -> FtlStats {
        self.ftl.stats()
    }

    /// When all in-flight flash + ECC work drains.
    pub fn drain_time(&self) -> SimTime {
        self.flash.drain_time().max(self.ecc.drain_time())
    }

    /// Busy seconds for the power model: (die, channel, ecc).
    pub fn busy_secs(&self) -> (f64, f64, f64) {
        (
            self.flash.die_busy_secs(),
            self.flash.channel_busy_secs(),
            self.ecc.busy_secs(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csd::CsdConfig;

    fn fcu() -> Fcu {
        Fcu::new(&CsdConfig::tiny())
    }

    #[test]
    fn page_alignment() {
        let f = fcu();
        assert_eq!(f.page_aligned(1), 4096);
        assert_eq!(f.page_aligned(4096), 4096);
        assert_eq!(f.page_aligned(4097), 8192);
    }

    #[test]
    fn lpn_range_spans_pages() {
        let f = fcu();
        assert_eq!(f.lpn_range(0, 4096), 0..1);
        assert_eq!(f.lpn_range(0, 4097), 0..2);
        assert_eq!(f.lpn_range(4000, 200), 0..2); // straddles a boundary
        assert_eq!(f.lpn_range(8192, 1), 2..3);
    }

    #[test]
    fn read_after_write_takes_time_and_counts() {
        let mut f = fcu();
        let w = f.write(0.0, 0, 16384, IoRequester::Host);
        assert!(w > 0.0);
        let r = f.read(w, 0, 16384, IoRequester::Isp);
        assert!(r > w);
        assert_eq!(f.io.host_write_bytes, 16384);
        assert_eq!(f.io.isp_read_bytes, 16384);
        assert_eq!(f.io.host_cmds, 1);
        assert_eq!(f.io.isp_cmds, 1);
    }

    #[test]
    fn multi_page_read_pipelines_ecc() {
        let mut f = fcu();
        let pages = 8u64;
        let w = f.write(0.0, 0, pages * 4096, IoRequester::Host);
        let r = f.read(w, 0, pages * 4096, IoRequester::Host);
        // With striping over 4 dies and pipelined ECC, total must be far
        // below pages × (tR + ecc) serial time.
        let serial = pages as f64 * (f.flash.cfg.read_secs + f.ecc_per_page);
        assert!(r - w < serial, "parallel read {r} vs serial {serial}");
    }

    /// Regression (ISSUE-8): unmapped pages are zero-filled by the FE —
    /// no flash op, no ECC decode. The read completes *at* `now`, and
    /// byte accounting still charges the requested extent.
    #[test]
    fn unwritten_extent_reads_fast() {
        let mut f = fcu();
        let r = f.read(0.0, 1 << 20, 4096, IoRequester::Host);
        assert_eq!(r, 0.0, "zero-fill must not charge ECC");
        assert_eq!(f.io.host_read_bytes, 4096);
        assert_eq!(f.io.host_cmds, 1);
        let (reads, _, _) = f.flash.counts();
        assert_eq!(reads, 0, "zero-fill must not touch flash");
        let r2 = f.read(7.5, 1 << 20, 4096, IoRequester::Isp);
        assert_eq!(r2, 7.5);
        assert_eq!(f.io.isp_read_bytes, 4096);
    }

    /// Background GC runs on idle dies and steals die/channel time from
    /// follow-on reads; it never changes host-visible IO accounting.
    #[test]
    fn background_gc_steals_bandwidth_from_follow_on_reads() {
        let churn = |bg: bool| {
            let mut cfg = CsdConfig::tiny();
            cfg.flash.background_gc = bg;
            let mut f = Fcu::new(&cfg);
            let page = cfg.flash.page_bytes;
            let hot = cfg.flash.total_pages() / 3;
            let mut t = 0.0;
            for round in 0..4u64 {
                for p in 0..hot {
                    let lpn = (p + round % 2) % hot;
                    t = f.write(t, lpn * page, page, IoRequester::Host);
                }
            }
            let r = f.read(t, 0, page, IoRequester::Host);
            (f, r - t)
        };
        let (f_off, delta_off) = churn(false);
        let (f_on, delta_on) = churn(true);
        assert_eq!(f_off.ftl_stats().background_gc_runs, 0);
        assert!(
            f_on.ftl_stats().background_gc_runs > 0,
            "idle dies below the bg watermark must collect: {:?}",
            f_on.ftl_stats()
        );
        assert!(
            delta_on >= delta_off,
            "bg relocation can only add contention: {delta_on} vs {delta_off}"
        );
        // Accounting is identical: GC is invisible to the host.
        assert_eq!(f_on.io.host_write_bytes, f_off.io.host_write_bytes);
        assert_eq!(f_on.io.host_cmds, f_off.io.host_cmds);
    }
}
