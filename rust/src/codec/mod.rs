//! Serialization substrates written from scratch (the offline build has no
//! `serde`): a complete JSON value model with parser and writer
//! ([`json`]), and the TOML subset used by experiment config files
//! ([`toml`]).

pub mod json;
pub mod toml;

pub use json::Json;
pub use toml::TomlTable;
