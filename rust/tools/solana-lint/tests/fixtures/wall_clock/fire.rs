// Positive fixture for D2 wall-clock: both clock types must fire.
use std::time::{Instant, SystemTime};

pub fn stamp() -> u64 {
    let t0 = Instant::now();
    let wall = SystemTime::now();
    let _ = (t0, wall);
    0
}
