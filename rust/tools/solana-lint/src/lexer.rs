//! A deliberately small Rust lexer: just enough token structure for the
//! D1–D6 rules (identifiers, literals, punctuation, comments with line
//! numbers). Not a parser — rules pattern-match token sequences.

/// Token kind. Strings/chars/lifetimes are kept distinct so rules can
/// skip literal content without re-scanning it.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Chr,
    Life,
    Punct,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// A comment (line or block), with the line its first character is on.
/// Comments never enter the token stream; markers are parsed from here.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

struct Cursor {
    chars: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Cursor {
    fn starts(&self, pat: &str) -> bool {
        let mut j = self.i;
        for p in pat.chars() {
            if j >= self.chars.len() || self.chars[j] != p {
                return false;
            }
            j += 1;
        }
        true
    }

    fn at(&self, off: usize) -> Option<char> {
        self.chars.get(self.i + off).copied()
    }

    fn text(&self, from: usize, to: usize) -> String {
        self.chars[from..to.min(self.chars.len())].iter().collect()
    }

    /// Advance `k` characters, tracking line/col.
    fn adv(&mut self, k: usize) {
        for _ in 0..k {
            if self.i < self.chars.len() && self.chars[self.i] == '\n' {
                self.line += 1;
                self.col = 1;
            } else {
                self.col += 1;
            }
            self.i += 1;
        }
    }
}

/// Tokenize `src`, returning `(tokens, comments)`.
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut cur = Cursor {
        chars: src.chars().collect(),
        i: 0,
        line: 1,
        col: 1,
    };
    let n = cur.chars.len();

    while cur.i < n {
        let c = cur.chars[cur.i];
        if c == ' ' || c == '\t' || c == '\r' || c == '\n' {
            cur.adv(1);
            continue;
        }
        // Line comment.
        if cur.starts("//") {
            let mut j = cur.i;
            while j < n && cur.chars[j] != '\n' {
                j += 1;
            }
            comments.push(Comment {
                line: cur.line,
                text: cur.text(cur.i, j),
            });
            let k = j - cur.i;
            cur.adv(k);
            continue;
        }
        // Block comment (nested).
        if cur.starts("/*") {
            let mut depth = 1usize;
            let mut j = cur.i + 2;
            while j < n && depth > 0 {
                if cur.chars[j] == '/' && j + 1 < n && cur.chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if cur.chars[j] == '*' && j + 1 < n && cur.chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            comments.push(Comment {
                line: cur.line,
                text: cur.text(cur.i, j),
            });
            let k = j - cur.i;
            cur.adv(k);
            continue;
        }
        // Raw strings r"..." / r#"..."# / br#"..."#.
        if let Some(len) = raw_string_len(&cur) {
            let (line, col) = (cur.line, cur.col);
            let text = cur.text(cur.i, cur.i + len);
            toks.push(Tok {
                kind: Kind::Str,
                text,
                line,
                col,
            });
            cur.adv(len);
            continue;
        }
        // Plain / byte strings.
        if c == '"' || cur.starts("b\"") {
            let start = cur.i;
            let mut j = cur.i + if cur.starts("b\"") { 2 } else { 1 };
            while j < n {
                if cur.chars[j] == '\\' {
                    j += 2;
                } else if cur.chars[j] == '"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            let (line, col) = (cur.line, cur.col);
            let text = cur.text(start, j);
            toks.push(Tok {
                kind: Kind::Str,
                text,
                line,
                col,
            });
            cur.adv(j - start);
            continue;
        }
        // Lifetime or char literal.
        if c == '\'' {
            if let Some((kind, len)) = tick_token(&cur) {
                let (line, col) = (cur.line, cur.col);
                let text = cur.text(cur.i, cur.i + len);
                toks.push(Tok {
                    kind,
                    text,
                    line,
                    col,
                });
                cur.adv(len);
            } else {
                cur.adv(1);
            }
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let mut j = cur.i + 1;
            while j < n && is_ident_cont(cur.chars[j]) {
                j += 1;
            }
            let (line, col) = (cur.line, cur.col);
            let text = cur.text(cur.i, j);
            let k = j - cur.i;
            toks.push(Tok {
                kind: Kind::Ident,
                text,
                line,
                col,
            });
            cur.adv(k);
            continue;
        }
        // Number (loose: digits then [0-9A-Za-z_.]*, trailing dots trimmed
        // so `0..n` ranges don't swallow the second bound).
        if c.is_ascii_digit() {
            let mut j = cur.i + 1;
            while j < n
                && (cur.chars[j].is_ascii_alphanumeric()
                    || cur.chars[j] == '_'
                    || cur.chars[j] == '.')
            {
                j += 1;
            }
            let mut text = cur.text(cur.i, j);
            while text.ends_with('.') {
                text.pop();
            }
            let k = text.chars().count();
            let (line, col) = (cur.line, cur.col);
            toks.push(Tok {
                kind: Kind::Num,
                text,
                line,
                col,
            });
            cur.adv(k);
            continue;
        }
        // Anything else: single-char punctuation.
        let (line, col) = (cur.line, cur.col);
        toks.push(Tok {
            kind: Kind::Punct,
            text: c.to_string(),
            line,
            col,
        });
        cur.adv(1);
    }
    (toks, comments)
}

/// Length of a raw/byte-raw string starting at the cursor, if any.
fn raw_string_len(cur: &Cursor) -> Option<usize> {
    let n = cur.chars.len();
    let mut j = cur.i;
    if cur.at(j - cur.i) == Some('b') {
        j += 1;
    }
    if cur.chars.get(j).copied() != Some('r') {
        return None;
    }
    j += 1;
    let hash_start = j;
    while j < n && cur.chars[j] == '#' {
        j += 1;
    }
    let hashes = j - hash_start;
    if cur.chars.get(j).copied() != Some('"') {
        return None;
    }
    j += 1;
    // Find closing `"` followed by the same number of hashes.
    while j < n {
        if cur.chars[j] == '"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < n && seen < hashes && cur.chars[k] == '#' {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return Some(k - cur.i);
            }
        }
        j += 1;
    }
    Some(n - cur.i)
}

/// Classify a `'`-led token as a lifetime or a char literal.
fn tick_token(cur: &Cursor) -> Option<(Kind, usize)> {
    let n = cur.chars.len();
    let next = cur.at(1)?;
    if is_ident_start(next) {
        let mut j = cur.i + 2;
        while j < n && is_ident_cont(cur.chars[j]) {
            j += 1;
        }
        if cur.chars.get(j).copied() != Some('\'') {
            // `'a` in `&'a T` — a lifetime.
            return Some((Kind::Life, j - cur.i));
        }
        if j == cur.i + 2 {
            // `'a'` — a one-char literal.
            return Some((Kind::Chr, 3));
        }
        return None;
    }
    if next == '\\' {
        // `'\n'`, `'\u{7f}'`, ... : escape then anything up to the quote.
        let mut j = cur.i + 3;
        while j < n && cur.chars[j] != '\'' {
            j += 1;
        }
        if j < n {
            return Some((Kind::Chr, j + 1 - cur.i));
        }
        return None;
    }
    if next != '\'' && cur.at(2) == Some('\'') {
        return Some((Kind::Chr, 3));
    }
    None
}

/// Match a fixed `(kind, optional text)` sequence starting at `i`.
pub fn match_seq(toks: &[Tok], i: usize, seq: &[(Kind, Option<&str>)]) -> bool {
    if i + seq.len() > toks.len() {
        return false;
    }
    for (k, (kind, text)) in seq.iter().enumerate() {
        let t = &toks[i + k];
        if t.kind != *kind {
            return false;
        }
        if let Some(want) = text {
            if t.text != *want {
                return false;
            }
        }
    }
    true
}
