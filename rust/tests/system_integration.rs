//! Cross-module integration: device stack (flash+FTL+FCU+FS) consistency,
//! failure injection, scheduler property sweeps, and CLI smoke.

use solana_isp::cluster::StorageServer;
use solana_isp::csd::{CsdConfig, IoRequester};
use solana_isp::fs::{LockMode, Mount, SharedFs};
use solana_isp::interconnect::TcpTunnel;
use solana_isp::metrics::Metrics;
use solana_isp::power::PowerModel;
use solana_isp::prop::{check, forall};
use solana_isp::sched::{run, SchedConfig};
use solana_isp::workloads::{App, AppModel};

// ---------------------------------------------------------------------
// Device stack
// ---------------------------------------------------------------------

#[test]
fn fs_extents_land_inside_flash_capacity() {
    let cfg = CsdConfig::tiny();
    let cap = cfg.flash.capacity_bytes();
    let mut fs = SharedFs::new(cap, 4096);
    fs.create("a", cap / 4).unwrap();
    fs.create("b", cap / 4).unwrap();
    let runs = fs.map_range("a", 0, cap / 4).unwrap();
    for (start, len) in runs {
        assert!(start + len <= cap);
    }
}

#[test]
fn ingest_read_roundtrip_counts_every_byte() {
    let mut s = StorageServer::new(2, CsdConfig::tiny());
    let bytes = 1 << 20;
    let t = s.ingest(0.0, 0, "data", bytes).unwrap();
    let r = s.isp_read(t, 0, "data", 0, bytes).unwrap();
    assert!(r.done > t);
    let io = s.bays[0].csd.fcu.io;
    assert_eq!(io.host_write_bytes, bytes);
    assert_eq!(io.isp_read_bytes, bytes);
    // flash-level accounting: at least bytes/page pages touched
    let (reads, programs, _) = s.bays[0].csd.fcu.flash.counts();
    assert!(programs >= bytes / 4096);
    assert!(reads >= bytes / 4096);
}

#[test]
fn failure_injection_fs_errors_surface() {
    let mut s = StorageServer::new(1, CsdConfig::tiny());
    // read of a file that was never ingested
    assert!(s.host_read(0.0, 0, "ghost", 0, 4096).is_err());
    // read past EOF
    s.ingest(0.0, 0, "small", 4096).unwrap();
    assert!(s.host_read(1.0, 0, "small", 0, 1 << 20).is_err());
    // duplicate ingest
    assert!(s.ingest(1.0, 0, "small", 4096).is_err());
    // drive out of space
    let cap = CsdConfig::tiny().flash.capacity_bytes();
    assert!(s.ingest(1.0, 0, "huge", cap * 2).is_err());
}

#[test]
fn dlm_traffic_is_bounded_by_lock_caching() {
    // Alternating readers only master the lock once per side.
    let mut fs = SharedFs::new(1 << 24, 4096);
    let mut tun = TcpTunnel::default();
    fs.create("shared", 1 << 20).unwrap();
    let mut t = 0.0;
    for _ in 0..100 {
        t = fs.lock(t, &mut tun, "shared", Mount::Host, LockMode::Read).unwrap();
        t = fs.lock(t, &mut tun, "shared", Mount::Isp, LockMode::Read).unwrap();
    }
    assert_eq!(fs.dlm.remote_grants, 2, "PR locks cache on both mounts");
    assert_eq!(fs.dlm.cached_hits, 198);
}

#[test]
fn gc_under_sustained_overwrite_keeps_device_usable() {
    let cfg = CsdConfig::tiny();
    let mut server = StorageServer::new(1, cfg.clone());
    let quarter = cfg.flash.capacity_bytes() / 4;
    server.ingest(0.0, 0, "hot", quarter).unwrap();
    // Overwrite *slices* of the hot file many times (partial-block
    // invalidation is what makes GC relocate valid pages → WAF > 1).
    let mut t = 1.0;
    let slice = quarter / 3;
    for round in 0..36u64 {
        let off = (round % 3) * slice;
        let bay = &mut server.bays[0];
        let runs = bay.fs.map_range("hot", off, slice).unwrap();
        for (dev_off, len) in runs {
            t = bay.csd.write(t, dev_off, len, IoRequester::Host).max(t);
        }
    }
    let stats = server.bays[0].csd.fcu.ftl_stats();
    assert!(stats.gc_runs > 0, "GC ran under churn: {stats:?}");
    assert!(stats.blocks_erased > 0, "{stats:?}");
    assert!(
        stats.waf() >= 1.0 && stats.waf() < 6.0,
        "sane WAF: {} ({stats:?})",
        stats.waf()
    );
    // device still serves reads
    let r = server.isp_read(t, 0, "hot", 0, quarter).unwrap();
    assert!(r.done > t);
}

// ---------------------------------------------------------------------
// Scheduler property sweeps
// ---------------------------------------------------------------------

#[test]
fn property_scheduler_conserves_items_across_configs() {
    forall("scheduler conservation", 12, |g| {
        let drives = g.usize(1..=36);
        let isp_drives = g.usize(0..=drives);
        let items = g.u64(1_000..=80_000);
        let batch = g.u64(10..=40_000);
        let ratio = g.f64(1.0, 30.0);
        let app = *g.rng().choose(&App::all());
        let model = AppModel::for_app(app, items);
        let cfg = SchedConfig {
            csd_batch: batch,
            batch_ratio: ratio,
            drives,
            isp_drives,
            ..SchedConfig::default()
        };
        let mut m = Metrics::new();
        let r = run(&model, &cfg, &PowerModel::default(), &mut m)
            .map_err(|e| e.to_string())?;
        check(
            r.host_items + r.csd_items == items,
            format!("lost items: {} + {} != {items}", r.host_items, r.csd_items),
        )?;
        check(r.makespan_secs > 0.0, "zero makespan")?;
        check(r.items_per_sec.is_finite(), "rate not finite")?;
        check(
            r.host_busy_secs <= r.makespan_secs + 1e-6,
            "host busy beyond makespan",
        )?;
        check(
            r.isp_busy_secs <= r.makespan_secs * drives as f64 + 1e-6,
            "isp busy beyond capacity",
        )?;
        if isp_drives == 0 {
            check(r.csd_items == 0, "baseline ran ISP work")?;
        }
        Ok(())
    });
}

#[test]
fn property_energy_consistent_with_power_bounds() {
    forall("energy within power envelope", 8, |g| {
        let drives = g.usize(1..=36);
        let items = g.u64(10_000..=200_000);
        let model = AppModel::sentiment(items);
        let cfg = SchedConfig {
            csd_batch: g.u64(1_000..=40_000),
            batch_ratio: 26.0,
            drives,
            isp_drives: drives,
            ..SchedConfig::default()
        };
        let p = PowerModel::default();
        let mut m = Metrics::new();
        let r = run(&model, &cfg, &p, &mut m).map_err(|e| e.to_string())?;
        let min_w = p.instantaneous_w(drives, 0.0, 0);
        let max_w = p.instantaneous_w(drives, 1.0, drives);
        check(
            r.avg_power_w >= min_w - 1e-6 && r.avg_power_w <= max_w + 1e-6,
            format!("avg power {} outside [{min_w}, {max_w}]", r.avg_power_w),
        )
    });
}

#[test]
fn adding_isp_drives_never_hurts_throughput_much() {
    // Monotonicity (within tolerance — tail quantization can cost a
    // little): engaging more ISP engines should not reduce throughput.
    let items = 1_000_000;
    let model = AppModel::sentiment(items);
    let power = PowerModel::default();
    let mut prev = 0.0;
    for isp in [0usize, 9, 18, 36] {
        let cfg = SchedConfig {
            csd_batch: 20_000,
            batch_ratio: 26.0,
            isp_drives: isp,
            ..SchedConfig::default()
        };
        let mut m = Metrics::new();
        let r = run(&model, &cfg, &power, &mut m).unwrap();
        assert!(
            r.items_per_sec > prev * 0.97,
            "throughput regressed at {isp} ISPs: {} < {prev}",
            r.items_per_sec
        );
        prev = r.items_per_sec;
    }
}

// ---------------------------------------------------------------------
// CLI smoke
// ---------------------------------------------------------------------

#[test]
fn cli_subcommands_smoke() {
    let sv = |xs: &[&str]| xs.iter().map(|s| s.to_string()).collect::<Vec<_>>();
    assert_eq!(solana_isp::exp::dispatch(&sv(&["version"])).unwrap(), 0);
    assert_eq!(solana_isp::exp::dispatch(&sv(&["power"])).unwrap(), 0);
    assert_eq!(
        solana_isp::exp::dispatch(&sv(&[
            "run", "--app", "speech", "--scale", "0.02", "--drives", "6", "--json"
        ]))
        .unwrap(),
        0
    );
    assert_eq!(
        solana_isp::exp::dispatch(&sv(&["run", "--app", "sentiment", "--scale", "0.01", "--baseline"]))
            .unwrap(),
        0
    );
}
