"""AOT pipeline integrity: every artifact lowers, the manifest matches the
emitted files, and the HLO text parses as an entry computation."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.lower_all(out, verbose=False)
    return out, manifest


def test_manifest_lists_all_files(built):
    out, manifest = built
    files = {a["file"] for a in manifest["artifacts"]}
    on_disk = {f for f in os.listdir(out) if f.endswith(".hlo.txt")}
    assert files == on_disk
    assert len(files) == len(manifest["artifacts"]), "no duplicate files"


def test_manifest_json_roundtrip(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == manifest
    assert loaded["format"] == 1
    assert loaded["dims"]["rec_topk"] == model.REC_TOPK


def test_hlo_text_has_entry_computation(built):
    out, manifest = built
    for a in manifest["artifacts"]:
        text = open(os.path.join(out, a["file"])).read()
        assert "ENTRY" in text, a["file"]
        assert "HloModule" in text, a["file"]


def test_input_arity_matches_signatures(built):
    _, manifest = built
    by_name = {}
    for a in manifest["artifacts"]:
        by_name.setdefault(a["name"], a)
    assert len(by_name["sentiment_infer"]["inputs"]) == 3
    assert len(by_name["sentiment_train_step"]["inputs"]) == 5
    assert len(by_name["recommender_topk"]["inputs"]) == 3
    assert len(by_name["acoustic_forward"]["inputs"]) == 7


def test_recommender_variants_cover_batch_sizes(built):
    _, manifest = built
    variants = {a["variant"] for a in manifest["artifacts"]
                if a["name"] == "recommender_topk"}
    assert variants == {"q1", "q32"}


def test_shapes_recorded_match_model_dims(built):
    _, manifest = built
    for a in manifest["artifacts"]:
        if a["name"] == "sentiment_infer":
            assert a["inputs"][0]["shape"][1] == model.SENT_FEATURES
        if a["name"] == "recommender_topk":
            assert a["inputs"][0]["shape"] == [model.REC_ITEMS, model.REC_DIM]
            assert a["outputs"][0]["shape"][1] == model.REC_TOPK
        if a["name"] == "acoustic_forward":
            assert a["outputs"][0]["shape"] == [
                model.SPEECH_FRAMES, model.SPEECH_VOCAB]
