"""L2: JAX compute graphs for the three NLP benchmarks (§IV-B).

Each function here is the *numerical* core of one benchmark app, built on
the L1 Pallas kernels and lowered once by ``aot.py`` to HLO text that the
rust runtime executes via PJRT.  Shapes are fixed per variant (PJRT
executables are static); the rust workloads pad/chunk to these shapes.

Benchmarks:

* **Sentiment analysis** (Sentiment140-style): hashed bag-of-words
  binary logistic regression.  ``sentiment_infer`` is the serving path;
  ``sentiment_train_step`` is one closed-form-gradient SGD step (the
  benchmark "uses labeled data to train a model" before serving).
* **Movie recommender** (MovieLens-style): cosine similarity of TF-IDF
  metadata vectors + popularity blend, top-10 (§IV-B2).
* **Speech-to-text** (LJSpeech/Vosk-style): framewise MLP acoustic model
  over MFCC-like features emitting CTC-style character log-probs; the
  rust side does the greedy collapse decode.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul, similarity

# ---------------------------------------------------------------------------
# Fixed model dimensions (shared with rust via the AOT manifest).
# ---------------------------------------------------------------------------

SENT_FEATURES = 4096      # hashing-vectorizer buckets
SENT_TRAIN_BATCH = 256

REC_ITEMS = 58_000        # movies in the catalogue (paper: 58k titles)
REC_DIM = 64              # TF-IDF projection dimension
REC_TOPK = 10             # top-10 similar movies (§IV-B2)

SPEECH_FRAMES = 100       # frames per inference chunk
SPEECH_FEATURES = 40      # MFCC-like coefficients
SPEECH_HIDDEN = 256
SPEECH_VOCAB = 29         # a-z + space + apostrophe + blank


# ---------------------------------------------------------------------------
# Sentiment analysis
# ---------------------------------------------------------------------------

def sentiment_infer(x, w, b):
    """P(positive) for a batch of hashed bag-of-words rows.

    x: [B, F] f32 (sparse counts, already hashed+normalized)
    w: [F, 1] f32, b: [1] f32
    returns probs [B] f32
    """
    logits = matmul(x, w)[:, 0] + b[0]
    return (jax.nn.sigmoid(logits),)


def sentiment_train_step(x, y, w, b, lr):
    """One SGD step of binary logistic regression (closed-form gradient).

    The gradient of mean BCE w.r.t. (w, b) is  X^T (p - y) / B  — written
    explicitly so the whole step lowers through the same tiled-GEMM
    kernel (forward *and* the X^T residual product).
    returns (w', b', mean_loss)
    """
    bsz = x.shape[0]
    logits = matmul(x, w)[:, 0] + b[0]
    p = jax.nn.sigmoid(logits)
    eps = 1e-7
    loss = -jnp.mean(y * jnp.log(p + eps) + (1.0 - y) * jnp.log(1.0 - p + eps))
    resid = (p - y)[:, None] / bsz            # [B, 1]
    grad_w = matmul(x.T, resid)               # [F, 1]
    grad_b = jnp.sum(resid)
    return (w - lr * grad_w, b - lr * grad_b, loss)


# ---------------------------------------------------------------------------
# Movie recommender
# ---------------------------------------------------------------------------

def recommender_topk(m, pop, q):
    """Top-K similar items for a batch of query vectors.

    m:   [N, D] f32 — L2-normalized TF-IDF item matrix
    pop: [N]    f32 — popularity/rating blend weight in [0, 1]
    q:   [Q, D] f32 — L2-normalized query vectors
    returns (scores [Q, K], indices [Q, K] i32)

    Cosine scores come from the Pallas tiled GEMM (the bandwidth-bound
    hot loop that runs in-storage); the "extra step" from §IV-B2 blends
    ratings/popularity before the top-10 filter.
    """
    scores = matmul(m, q.T)                   # [N, Q]
    blended = (scores * (0.5 + 0.5 * pop[:, None])).T  # [Q, N]
    # top-k via a full descending argsort: jax.lax.top_k lowers to the
    # `topk(..., largest=true)` HLO op, which the runtime's XLA text
    # parser (xla_extension 0.5.1) predates — sort/gather parse fine.
    order = jnp.argsort(-blended, axis=1)[:, :REC_TOPK]      # [Q, K] i32
    vals = jnp.take_along_axis(blended, order, axis=1)       # [Q, K]
    return (vals, order.astype(jnp.int32))


def recommender_scores_one(m, q):
    """Single-query raw similarity scores (diagnostics / kernel tests)."""
    return (similarity(m, q),)


# ---------------------------------------------------------------------------
# Speech to text
# ---------------------------------------------------------------------------

def acoustic_forward(frames, w1, b1, w2, b2, w3, b3):
    """Framewise acoustic model: 2 hidden layers + character log-probs.

    frames: [T, F] f32 MFCC-like features
    returns log_probs [T, V] f32
    """
    h1 = jax.nn.relu(matmul(frames, w1) + b1)
    h2 = jax.nn.relu(matmul(h1, w2) + b2)
    logits = matmul(h2, w3) + b3
    return (jax.nn.log_softmax(logits, axis=-1),)


def acoustic_param_shapes():
    """Parameter shapes, shared with the rust side via the manifest."""
    return {
        "w1": (SPEECH_FEATURES, SPEECH_HIDDEN),
        "b1": (SPEECH_HIDDEN,),
        "w2": (SPEECH_HIDDEN, SPEECH_HIDDEN),
        "b2": (SPEECH_HIDDEN,),
        "w3": (SPEECH_HIDDEN, SPEECH_VOCAB),
        "b3": (SPEECH_VOCAB,),
    }
