//! End-to-end driver: the full system, all layers composing.
//!
//! 1. **Real compute** — for each of the three NLP benchmarks, run a
//!    representative sample through the actual AOT/PJRT executables
//!    (train sentiment, serve recommendations, transcribe speech) and
//!    verify output quality (accuracy / top-k sanity / WER).
//! 2. **Full-cluster simulation** — replay each benchmark at paper scale
//!    on the simulated 36-CSD AIC server (flash, FTL, shared FS,
//!    tunnel, scheduler, power) and regenerate the paper's headline
//!    numbers: Fig 5 best points, Table I speedups/energy, data split.
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_cluster
//! ```

use solana_isp::metrics::{Metrics, Table};
use solana_isp::nlp::corpus::{MovieCatalog, SpeechCorpus, TweetCorpus};
use solana_isp::power::PowerModel;
use solana_isp::runtime::Engine;
use solana_isp::sched::{run, SchedConfig};
use solana_isp::util::human_bytes;
use solana_isp::workloads::{App, AppModel, RecommenderApp, SentimentApp, SpeechApp};

struct PaperPoint {
    app: App,
    batch: u64,
    ratio: f64,
    paper_base: f64,
    paper_isp: f64,
    paper_speedup: f64,
    paper_saving_pct: f64,
    paper_csd_share_pct: f64,
}

const POINTS: [PaperPoint; 3] = [
    PaperPoint {
        app: App::SpeechToText,
        batch: 6,
        ratio: 20.0,
        paper_base: 96.0,
        paper_isp: 296.0,
        paper_speedup: 3.1,
        paper_saving_pct: 67.0,
        paper_csd_share_pct: 68.0,
    },
    PaperPoint {
        app: App::Recommender,
        batch: 256,
        ratio: 22.0,
        paper_base: 579.0,
        paper_isp: 1506.0,
        paper_speedup: 2.6,
        paper_saving_pct: 61.0,
        paper_csd_share_pct: 64.0,
    },
    PaperPoint {
        app: App::Sentiment,
        batch: 40_000,
        ratio: 26.0,
        paper_base: 9_496.0,
        paper_isp: 20_994.0,
        paper_speedup: 2.2,
        paper_saving_pct: 54.0,
        paper_csd_share_pct: 56.0,
    },
];

fn phase1_real_compute(eng: &mut Engine) -> anyhow::Result<()> {
    println!("── phase 1: real compute through PJRT ───────────────────────");

    // Sentiment: train + accuracy.
    let mut tweets = TweetCorpus::new(11);
    let train = tweets.take(4_096);
    let test = tweets.take(1_024);
    let (sent, losses) = SentimentApp::train(eng, &train, 3, 5)?;
    let acc = sent.accuracy(eng, &test)?;
    println!(
        "sentiment   : loss {:.3}→{:.3}, accuracy {:.1}% on {} held-out tweets",
        losses.first().unwrap(),
        losses.last().unwrap(),
        acc * 100.0,
        test.len()
    );
    anyhow::ensure!(acc > 0.85, "sentiment accuracy {acc}");

    // Recommender: build + top-10 sanity on a 58k catalogue.
    let catalog = MovieCatalog::generate(7, 58_000);
    let rec = RecommenderApp::build(eng, catalog)?;
    let qs: Vec<u32> = rec.catalog.shuffled_query_ids(3)[..16].to_vec();
    let recs = rec.recommend(eng, &qs)?;
    let filled = recs.iter().filter(|r| !r.is_empty()).count();
    println!(
        "recommender : {}/{} queries returned top-10 lists over 58,000 titles",
        filled,
        qs.len()
    );
    anyhow::ensure!(filled == qs.len());

    // Speech: transcribe + WER.
    let corpus = SpeechCorpus::generate(2024, 24);
    let speech = SpeechApp::new(eng, corpus)?;
    let ids: Vec<u32> = (0..24).collect();
    let (wer, _) = speech.transcribe_set(eng, &ids, 7)?;
    println!("speech      : mean WER {:.3} over 24 clips", wer);
    anyhow::ensure!(wer < 0.12, "speech WER {wer}");

    println!("total PJRT executions: {}\n", eng.executions());
    Ok(())
}

fn phase2_cluster() -> anyhow::Result<()> {
    println!("── phase 2: full-cluster simulation (36 CSDs, paper scale) ──");
    let power = PowerModel::default();
    let mut table = Table::new(
        "paper vs reproduced (best configuration per app)",
        &[
            "app",
            "base (ours/paper)",
            "w/ ISP (ours/paper)",
            "speedup (ours/paper)",
            "energy saving (ours/paper)",
            "csd share (ours/paper)",
        ],
    );
    for p in &POINTS {
        let items = AppModel::paper_items(p.app);
        let model = AppModel::for_app(p.app, items);
        let mut m = Metrics::new();
        let cfg = SchedConfig {
            csd_batch: p.batch,
            batch_ratio: p.ratio,
            ..SchedConfig::default()
        };
        // Baseline shares the batch configuration — only the ISP engines
        // are disabled (the paper's "same server, ISP disabled").
        let base = run(&model, &SchedConfig { isp_drives: 0, ..cfg.clone() }, &power, &mut m)?;
        let isp = run(&model, &cfg, &power, &mut m)?;
        let (ours_base, ours_isp) = if p.app == App::SpeechToText {
            (base.words_per_sec, isp.words_per_sec)
        } else {
            (base.items_per_sec, isp.items_per_sec)
        };
        let speedup = ours_isp / ours_base;
        let saving = (1.0 - isp.energy_per_item_j / base.energy_per_item_j) * 100.0;
        table.row(vec![
            p.app.name().to_string(),
            format!("{ours_base:.0} / {:.0}", p.paper_base),
            format!("{ours_isp:.0} / {:.0}", p.paper_isp),
            format!("{speedup:.1}x / {:.1}x", p.paper_speedup),
            format!("{saving:.0}% / {:.0}%", p.paper_saving_pct),
            format!(
                "{:.0}% / {:.0}%",
                isp.csd_data_fraction() * 100.0,
                p.paper_csd_share_pct
            ),
        ]);
        if p.app == App::SpeechToText {
            println!(
                "speech data: {} stayed in storage, {} crossed PCIe (paper: 2.58 GB stayed of 3.8 GB)",
                human_bytes(isp.isp_bytes),
                human_bytes(isp.pcie_bytes)
            );
        }
    }
    print!("\n{}", table.render());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    match Engine::load_default() {
        Some(mut eng) => phase1_real_compute(&mut eng)?,
        None => println!("(artifacts not built — skipping real-compute phase; run `make artifacts`)\n"),
    }
    phase2_cluster()?;
    println!("\ne2e driver completed in {:.1}s wall", t0.elapsed().as_secs_f64());
    Ok(())
}
