//! The reproduction gate: every headline claim in the paper, asserted at
//! full paper scale against the simulated testbed. Tolerances are wide
//! enough for a different substrate but tight enough that the *shape* —
//! who wins, by roughly what factor — must hold.

use solana_isp::metrics::Metrics;
use solana_isp::power::PowerModel;
use solana_isp::sched::{run, RunReport, SchedConfig};
use solana_isp::workloads::{App, AppModel};

fn pair(app: App, items: u64, batch: u64, ratio: f64) -> (RunReport, RunReport) {
    let model = AppModel::for_app(app, items);
    let power = PowerModel::default();
    let cfg = SchedConfig { csd_batch: batch, batch_ratio: ratio, ..SchedConfig::default() };
    let mut m = Metrics::new();
    let base = run(&model, &SchedConfig { isp_drives: 0, ..cfg.clone() }, &power, &mut m).unwrap();
    let isp = run(&model, &cfg, &power, &mut m).unwrap();
    (base, isp)
}

#[test]
fn speech_fig5a_headline() {
    // Paper: 96 → 296 words/s with 36 CSDs (3.1x), batch size 6.
    let (base, isp) = pair(App::SpeechToText, 13_100, 6, 20.0);
    assert!((90.0..112.0).contains(&base.words_per_sec), "base {}", base.words_per_sec);
    assert!((255.0..320.0).contains(&isp.words_per_sec), "isp {}", isp.words_per_sec);
    let speedup = isp.words_per_sec / base.words_per_sec;
    assert!((2.5..3.4).contains(&speedup), "speedup {speedup}");
}

#[test]
fn speech_batch_insensitivity() {
    // Paper: "processing speed does not change much (less than 7%) when
    // varying the batch size" — we allow 12% across 2..8.
    let mut rates = Vec::new();
    for batch in [2u64, 4, 6, 8] {
        let (_, isp) = pair(App::SpeechToText, 13_100, batch, 20.0);
        rates.push(isp.words_per_sec);
    }
    let max = rates.iter().cloned().fold(0.0, f64::max);
    let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!((max - min) / max < 0.12, "batch sensitivity {rates:?}");
}

#[test]
fn speech_data_transfer_reduction() {
    // Paper: 68% of the input never left the storage units; only ~1.2 MB
    // of text came back.
    let (_, isp) = pair(App::SpeechToText, 13_100, 6, 20.0);
    let frac = isp.csd_data_fraction();
    assert!((0.55..0.75).contains(&frac), "csd share {frac}");
    let total_bytes = 13_100u64 * 290_000;
    let stayed = isp.isp_bytes as f64 / total_bytes as f64;
    assert!(stayed > 0.5, "in-storage byte share {stayed}");
}

#[test]
fn recommender_fig5b_headline() {
    // Paper: 579 → 1506 q/s (2.6x).
    let (base, isp) = pair(App::Recommender, 58_000, 256, 22.0);
    assert!((530.0..600.0).contains(&base.items_per_sec), "base {}", base.items_per_sec);
    let speedup = isp.items_per_sec / base.items_per_sec;
    assert!((2.2..2.9).contains(&speedup), "speedup {speedup}");
}

#[test]
fn sentiment_fig5c_headline() {
    // Paper: 9496 → 20994 q/s (2.2x) at batch 40k over 8M tweets.
    let (base, isp) = pair(App::Sentiment, 8_000_000, 40_000, 26.0);
    assert!((9_000.0..9_800.0).contains(&base.items_per_sec), "base {}", base.items_per_sec);
    let speedup = isp.items_per_sec / base.items_per_sec;
    assert!((1.9..2.5).contains(&speedup), "speedup {speedup}");
}

#[test]
fn sentiment_fig5c_batch_sweep_shape() {
    // Fig 5(c): across the paper's sweep {10k, 20k, 40k, 80k} every point
    // lands near the 2.2x speedup with a modest spread. (Which exact
    // batch peaks depends on tail quantization; the paper measured 40k
    // best by a small margin — see EXPERIMENTS.md §Deviations.)
    let mut speedups = Vec::new();
    for batch in [10_000u64, 20_000, 40_000, 80_000] {
        let (base, isp) = pair(App::Sentiment, 4_000_000, batch, 26.0);
        speedups.push(isp.items_per_sec / base.items_per_sec);
    }
    for (i, s) in speedups.iter().enumerate() {
        assert!((1.8..2.6).contains(s), "batch idx {i}: speedup {s}");
    }
    let max = speedups.iter().cloned().fold(0.0, f64::max);
    let min = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!((max - min) / max < 0.25, "spread too wide: {speedups:?}");
}

#[test]
fn table1_energy_savings() {
    // Paper Table I: energy saving per query 67% / 61% / 54%.
    for (app, items, batch, ratio, expect) in [
        (App::SpeechToText, 13_100u64, 6u64, 20.0, 0.67),
        (App::Recommender, 58_000, 256, 22.0, 0.61),
        (App::Sentiment, 8_000_000, 40_000, 26.0, 0.54),
    ] {
        let (base, isp) = pair(app, items, batch, ratio);
        let saving = 1.0 - isp.energy_per_item_j / base.energy_per_item_j;
        assert!(
            (saving - expect).abs() < 0.10,
            "{app:?}: saving {saving:.2} vs paper {expect}"
        );
    }
}

#[test]
fn table1_data_split() {
    // Paper Table I: data processed in CSDs 68% / 64% / 56%.
    for (app, items, batch, ratio, expect) in [
        (App::SpeechToText, 13_100u64, 6u64, 20.0, 0.68),
        (App::Recommender, 58_000, 256, 22.0, 0.64),
        (App::Sentiment, 8_000_000, 40_000, 26.0, 0.56),
    ] {
        let (_, isp) = pair(app, items, batch, ratio);
        let share = isp.csd_data_fraction();
        assert!(
            (share - expect).abs() < 0.08,
            "{app:?}: csd share {share:.2} vs paper {expect}"
        );
    }
}

#[test]
fn fig6_single_node_rates() {
    // Fig 6 endpoints: host saturates ≈9496 q/s, CSD ≈364 q/s at 40k.
    let m = AppModel::sentiment(1);
    let host = m.node_rate_at_batch(40_000, true);
    let csd = m.node_rate_at_batch(40_000, false);
    assert!((host - 9_496.0).abs() / 9_496.0 < 0.03, "host {host}");
    assert!((csd - 364.0).abs() / 364.0 < 0.03, "csd {csd}");
    // ratio ≈ 26 (the paper sets the batch ratio from exactly this)
    let ratio = host / csd;
    assert!((ratio - 26.0).abs() < 1.0, "ratio {ratio}");
}

#[test]
fn fig7_energy_monotone_in_csds() {
    // Fig 7: normalized energy/query decreases as CSDs are engaged.
    for app in App::all() {
        let items = AppModel::paper_items(app) / 4;
        let batch = match app {
            App::SpeechToText => 6,
            App::Recommender => 256,
            App::Sentiment => 40_000,
        };
        let model = AppModel::for_app(app, items);
        let power = PowerModel::default();
        let mut last = f64::INFINITY;
        for csds in [0usize, 9, 36] {
            let mut m = Metrics::new();
            let cfg = SchedConfig {
                csd_batch: batch,
                batch_ratio: 22.0,
                isp_drives: csds,
                ..SchedConfig::default()
            };
            let r = run(&model, &cfg, &power, &mut m).unwrap();
            assert!(
                r.energy_per_item_j < last * 1.001,
                "{app:?}: energy/query rose at {csds} CSDs"
            );
            last = r.energy_per_item_j;
        }
    }
}

#[test]
fn determinism_same_seed_same_report() {
    let model = AppModel::sentiment(300_000);
    let cfg = SchedConfig { csd_batch: 20_000, batch_ratio: 26.0, ..SchedConfig::default() };
    let power = PowerModel::default();
    let mut m1 = Metrics::new();
    let mut m2 = Metrics::new();
    let a = run(&model, &cfg, &power, &mut m1).unwrap();
    let b = run(&model, &cfg, &power, &mut m2).unwrap();
    assert_eq!(a.makespan_secs, b.makespan_secs);
    assert_eq!(a.host_items, b.host_items);
    assert_eq!(a.pcie_bytes, b.pcie_bytes);
    assert_eq!(a.energy_j, b.energy_j);
    assert_eq!(a.tunnel_messages, b.tunnel_messages);
}
