//! Discrete-event simulation core.
//!
//! The full-system model (CSDs, links, scheduler, power meter) runs in
//! *virtual time* on this engine, which is what lets one machine
//! reproduce a 36-drive storage server deterministically.
//!
//! Two complementary mechanisms:
//!
//! * [`EventQueue`] — a classic event calendar: `(time, seq, E)` entries
//!   popped in time order with a strictly monotonic sequence number as a
//!   tie-break, so same-timestamp events replay in schedule order and the
//!   whole simulation is bit-reproducible.
//! * [`Servers`] / [`Pipe`] — *analytic* FIFO resources. With
//!   non-preemptive service and known durations, a k-server queue's
//!   completion time is `max(now, earliest_free_server) + service`; a
//!   shared link serializes transfers on its busy-until horizon. Device
//!   models use these to compute contention without flooding the event
//!   calendar, which keeps full Fig-5 sweeps (hundreds of millions of
//!   simulated queries) fast.

pub mod queue;
pub mod resource;

pub use queue::EventQueue;
pub use resource::{Pipe, Servers, Transfer};

/// Simulated time in seconds.
pub type SimTime = f64;

/// Epsilon used when comparing simulated times in assertions.
pub const TIME_EPS: f64 = 1e-9;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combined_queue_and_servers() {
        // Two jobs contend for one server; completions land in order.
        #[derive(Debug, PartialEq)]
        enum Ev {
            Done(u32),
        }
        let mut q = EventQueue::new();
        let mut cpu = Servers::new(1);
        let d1 = cpu.acquire(0.0, 2.0);
        let d2 = cpu.acquire(0.0, 2.0);
        q.schedule_at(d1, Ev::Done(1));
        q.schedule_at(d2, Ev::Done(2));
        let (t1, e1) = q.pop().unwrap();
        let (t2, e2) = q.pop().unwrap();
        assert_eq!((t1, e1), (2.0, Ev::Done(1)));
        assert_eq!((t2, e2), (4.0, Ev::Done(2)));
        assert!(q.pop().is_none());
    }
}
