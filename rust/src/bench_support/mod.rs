//! Mini benchmark harness (the offline build has no `criterion`).
//!
//! Provides warmup + timed iterations with mean/p50/p99 reporting and a
//! machine-readable JSON dump. `cargo bench` targets in `benches/` use
//! `harness = false` and drive this module; each bench binary regenerates
//! one figure or table from the paper (see DESIGN.md §6).

use std::time::Instant;

use crate::codec::json::Json;
use crate::util::stats::Summary;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs_per_iter: Summary,
    /// Optional user-defined throughput metric (items/sec based on mean).
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str().into())
            .set("iters", self.iters.into())
            .set("mean_s", self.secs_per_iter.mean.into())
            .set("p50_s", self.secs_per_iter.p50.into())
            .set("p99_s", self.secs_per_iter.p99.into())
            .set("std_s", self.secs_per_iter.std.into());
        if let Some(t) = self.throughput {
            j.set("throughput", t.into());
        }
        j
    }
}

/// Benchmark runner: fixed warmup iterations then timed iterations.
pub struct Bencher {
    pub warmup_iters: usize,
    pub iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new(3, 10)
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, iters: usize) -> Bencher {
        Bencher { warmup_iters, iters, results: Vec::new() }
    }

    /// Honour `SOLANA_BENCH_FAST=1` to shrink iteration counts (CI).
    pub fn from_env() -> Bencher {
        if std::env::var("SOLANA_BENCH_FAST").ok().as_deref() == Some("1") {
            Bencher::new(1, 3)
        } else {
            Bencher::default()
        }
    }

    /// Time `f`, which returns a per-iteration "items processed" count
    /// used for throughput (pass 0 to skip).
    pub fn bench<F>(&mut self, name: &str, mut f: F) -> &BenchResult
    where
        F: FnMut() -> u64,
    {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        let mut items_total: u64 = 0;
        for _ in 0..self.iters {
            // solana-lint: allow(wall-clock, reason = "bench_support measures real elapsed time by definition; it never runs inside the simulator")
            let t0 = Instant::now();
            let items = std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            items_total += items;
        }
        // solana-lint: allow(no-unwrap, reason = "iters is a non-zero construction constant, so samples is never empty")
        let summary = Summary::of(&samples).expect("at least one iteration");
        let throughput = if items_total > 0 {
            Some(items_total as f64 / self.iters as f64 / summary.mean)
        } else {
            None
        };
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: self.iters,
            secs_per_iter: summary,
            throughput,
        });
        // solana-lint: allow(no-unwrap, reason = "a result was pushed on the line above")
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render all results as an aligned text report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<52} {:>12} {:>12} {:>12} {:>14}\n",
            "benchmark", "mean", "p50", "p99", "throughput"
        ));
        for r in &self.results {
            out.push_str(&format!(
                "{:<52} {:>12} {:>12} {:>12} {:>14}\n",
                r.name,
                crate::util::human_secs(r.secs_per_iter.mean),
                crate::util::human_secs(r.secs_per_iter.p50),
                crate::util::human_secs(r.secs_per_iter.p99),
                r.throughput
                    .map(|t| format!("{t:.1}/s"))
                    .unwrap_or_else(|| "-".to_string()),
            ));
        }
        out
    }

    /// JSON array of all results.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.results.iter().map(|r| r.to_json()).collect())
    }

    /// Write the JSON report under `target/bench-results/<file>.json`.
    pub fn write_json(&self, file: &str) -> std::io::Result<()> {
        let dir = std::path::Path::new("target/bench-results");
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{file}.json")), self.to_json().to_pretty())
    }

    /// Promote this run's results to a committable *trajectory point*:
    /// `<dir>/BENCH_NNNN.json` with `NNNN` the first free index, so
    /// successive toolchain-equipped runs accumulate a performance
    /// history alongside the ephemeral `target/bench-results` dumps.
    /// `perf_micro` calls this when `SOLANA_BENCH_TRAJECTORY=1` (CI sets
    /// it and uploads the directory as an artifact; committing the file
    /// records the point).
    pub fn write_trajectory_in(
        &self,
        dir: &std::path::Path,
        bench: &str,
    ) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let mut n = 1u32;
        let path = loop {
            let p = dir.join(format!("BENCH_{n:04}.json"));
            if !p.exists() {
                break p;
            }
            n += 1;
        };
        // solana-lint: allow(wall-clock, reason = "the bench-trajectory point records when the benchmark ran on the host; simulated time is meaningless here")
        let unix_time = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let mut j = Json::obj();
        j.set("bench", bench.into())
            .set("unix_time", unix_time.into())
            .set("results", self.to_json());
        std::fs::write(&path, j.to_pretty())?;
        Ok(path)
    }

    /// [`Bencher::write_trajectory_in`] under `bench-trajectory/` at the
    /// **workspace root**. Bench binaries run with their working
    /// directory set to the *package* root (`rust/`), not the workspace
    /// root, so the directory is anchored off the compile-time
    /// `CARGO_MANIFEST_DIR` rather than the cwd — the committable file
    /// always lands at `<repo>/bench-trajectory/BENCH_NNNN.json`.
    pub fn write_trajectory(&self, bench: &str) -> std::io::Result<std::path::PathBuf> {
        let pkg = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
        let root = pkg.parent().unwrap_or(pkg);
        self.write_trajectory_in(&root.join("bench-trajectory"), bench)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples_and_throughput() {
        let mut b = Bencher::new(1, 5);
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
            10_000
        });
        assert_eq!(r.iters, 5);
        assert!(r.secs_per_iter.mean > 0.0);
        assert!(r.throughput.unwrap() > 0.0);
    }

    #[test]
    fn trajectory_points_number_sequentially() {
        let dir = std::path::Path::new("target/test-bench-trajectory");
        let _ = std::fs::remove_dir_all(dir);
        let mut b = Bencher::new(0, 1);
        b.bench("case", || 1);
        let p1 = b.write_trajectory_in(dir, "perf_micro").unwrap();
        let p2 = b.write_trajectory_in(dir, "perf_micro").unwrap();
        assert!(p1.ends_with("BENCH_0001.json"), "{p1:?}");
        assert!(p2.ends_with("BENCH_0002.json"), "{p2:?}");
        let text = std::fs::read_to_string(&p1).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").and_then(|b| b.as_str()), Some("perf_micro"));
        assert!(j.get("results").is_some());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn report_and_json_include_all_cases() {
        let mut b = Bencher::new(0, 2);
        b.bench("a", || 1);
        b.bench("b", || 0);
        let rep = b.report();
        assert!(rep.contains("a") && rep.contains("b"));
        let j = b.to_json();
        assert_eq!(j.as_arr().unwrap().len(), 2);
        // case "b" had zero items → no throughput key
        assert!(j.as_arr().unwrap()[1].get("throughput").is_none());
    }
}
