// Negative fixture for D6 join-reduce: test code may spawn threads
// (loopback integration tests do).
#[cfg(test)]
mod tests {
    use std::thread;

    #[test]
    fn spawn_in_tests_is_fine() {
        thread::spawn(|| ()).join().unwrap();
    }
}
