//! Deterministic PRNG: xoshiro256++ seeded via splitmix64.
//!
//! The offline build has no `rand` crate; this is the project's only
//! randomness source. xoshiro256++ is the same generator family the
//! `rand_xoshiro` crate ships; splitmix64 seeding follows the reference
//! implementation (Blackman & Vigna). Determinism matters: every
//! experiment in EXPERIMENTS.md is keyed by an explicit seed.

/// splitmix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a named sub-component.
    /// Hashes the label into the seed so `fork("flash")` and
    /// `fork("sched")` never correlate.
    pub fn fork(&mut self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(self.next_u64() ^ h)
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough for
    /// simulation purposes via rejection).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Simple rejection sampling on the top bits.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// deterministic, throughput is irrelevant here).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std, truncated at `lo`.
    pub fn gaussian_trunc(&mut self, mean: f64, std: f64, lo: f64) -> f64 {
        (mean + std * self.gaussian()).max(lo)
    }

    /// Exponential with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Zipf-like rank sample over `[0, n)` with exponent `s` (used for
    /// skewed query popularity in the recommender workload). Uses
    /// inverse-CDF over a precomputable harmonic approximation; for the
    /// modest `n` here a direct rejection method is fine.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n > 0);
        // Approximate inverse CDF: P(rank < k) ≈ (k/n)^(1-s) for s<1;
        // for general s use the standard rejection-inversion lite:
        if s <= 0.0 {
            return self.below(n);
        }
        let nf = n as f64;
        loop {
            let u = self.f64();
            // inverse of integral of x^-s from 1..n
            let x = if (s - 1.0).abs() < 1e-9 {
                nf.powf(u)
            } else {
                let t = 1.0 - s;
                ((nf.powf(t) - 1.0) * u + 1.0).powf(1.0 / t)
            };
            let k = x.floor() as u64;
            if k >= 1 && k <= n {
                return k - 1;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(1234);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_skewed_towards_low_ranks() {
        let mut r = Rng::new(5);
        let n = 1000u64;
        let mut low = 0usize;
        let total = 20_000;
        for _ in 0..total {
            if r.zipf(n, 1.0) < 100 {
                low += 1;
            }
        }
        // Zipf(1.0): top-10% of ranks should hold well over 10% of mass.
        assert!(low as f64 / total as f64 > 0.25, "low mass {low}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffled order changed");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(3);
        let mut a = root.fork("flash");
        let mut b = root.fork("sched");
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(21);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }
}
