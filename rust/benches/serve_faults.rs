//! `cargo bench --bench serve_faults` — regenerates Fig 11: availability
//! under deterministic fault injection (lost acks, drive stalls, a
//! permanent server crash) across front-door resilience policies
//! (timeouts+retries, hedging, shard failover) for the all-CSD build and
//! the all-SSD baseline — the ISSUE-6 tentpole. See `faults` for the
//! fault plan, `traffic::balancer` for the failure plane, and `exp` for
//! the sweep definition.
//!
//! Scale with `SOLANA_BENCH_FAST=1` (5%) or default 25% of the paper's
//! dataset sizes; the *shape* (fire-and-forget collapses under a crash,
//! retry+hedge+replica holds ≥ 99% availability) is scale-invariant.

use solana_isp::bench_support::Bencher;
use solana_isp::exp::{self, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_env();
    let table = exp::fig11_availability(scale)?;
    exp::emit(&table, "fig11")?;
    // Wall-time of regenerating the artifact (simulator throughput):
    let mut b = Bencher::new(0, if std::env::var("SOLANA_BENCH_FAST").is_ok() { 1 } else { 2 });
    b.bench("fig11_serve_faults", || {
        let t = exp::fig11_availability(scale).expect("rerun");
        t.rows.len() as u64
    });
    print!("{}", b.report());
    b.write_json("serve_faults")?;
    Ok(())
}
